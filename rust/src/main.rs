//! `spz` — thin CLI adapter over the typed [`sparsezipper::api`] Session API
//! (hand-rolled arg parsing; the offline vendor set has no clap).
//!
//! All experiment orchestration lives in the library: this binary only
//! parses argv into [`JobSpec`]/[`SuiteSpec`] values, hands them to a
//! [`Session`], and renders the results.
//!
//! ```text
//! spz table3|fig8|fig9|fig10|fig11|table4|all [--scale F] [--threads N]
//!     [--datasets a,b,...] [--impls a,b,...] [--engine native|xla]
//!     [--verify] [--json] [--out-dir DIR] [--mtx-dir DIR]
//! spz run --dataset NAME --impl NAME [--scale F] [--engine native|xla] [--json]
//! spz isa | config | gen --dataset NAME --out FILE.mtx [--scale F]
//! ```

use anyhow::{bail, Context, Result};
use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig, SuiteSpec};
use sparsezipper::area::AreaModel;
use sparsezipper::coordinator::{figures, report};
use sparsezipper::matrix::registry;
use sparsezipper::runtime::Engine;
use sparsezipper::spgemm::parallel::Scheduler;
use sparsezipper::ImplId;
use std::path::{Path, PathBuf};

struct Args {
    cmd: String,
    opts: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

/// Strict argv parsing for everything after the subcommand. Boolean flags
/// are listed explicitly; any other `--key` expects a value and may appear
/// at most once (a duplicate is an error, not a silent overwrite).
const COMMANDS: &[&str] = &[
    "table3", "fig4", "fig8", "fig9", "fig10", "fig11", "fig12", "table4", "all", "run", "mem",
    "ablate", "isa", "config", "gen", "serve-demo",
];

fn parse_argv(args: &[String]) -> Result<Args> {
    let mut it = args.iter();
    let cmd = it.next().cloned().unwrap_or_else(|| "help".to_string());
    // Diagnose a typo'd command before complaining about its options.
    if !COMMANDS.contains(&cmd.as_str()) {
        bail!("unknown command '{cmd}' (try: spz help)");
    }
    let mut opts = std::collections::HashMap::new();
    let mut flags = std::collections::HashSet::new();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            match key {
                "verify" | "quiet" | "sweep" | "json" => {
                    flags.insert(key.to_string());
                }
                _ => {
                    let v = it.next().with_context(|| format!("--{key} needs a value"))?;
                    if opts.insert(key.to_string(), v.clone()).is_some() {
                        bail!("duplicate option --{key}");
                    }
                }
            }
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    for key in opts.keys() {
        if !allowed_opts(&cmd).contains(&key.as_str()) {
            bail!("unknown option --{key} for '{cmd}' (try: spz help)");
        }
    }
    for flag in &flags {
        if !allowed_flags(&cmd).contains(&flag.as_str()) {
            bail!("flag --{flag} does not apply to '{cmd}' (try: spz help)");
        }
    }
    Ok(Args { cmd, opts, flags })
}

/// Value-taking options each command accepts; a typo'd or misplaced option
/// is an error rather than a silently ignored map entry.
fn allowed_opts(cmd: &str) -> &'static [&'static str] {
    const SUITE: &[&str] = &[
        "scale", "threads", "datasets", "engine", "artifacts", "mtx-dir", "out-dir", "cores",
        "sched", "sockets", "replay-shards", "trace-ring-chunks", "page-placement",
    ];
    match cmd {
        // Only fig8/all honor --impls; the other figures fix their own
        // implementation set, so accepting it would silently discard it.
        "fig8" | "all" => &[
            "scale", "threads", "datasets", "impls", "engine", "artifacts", "mtx-dir", "out-dir",
            "cores", "sched", "sockets", "replay-shards", "trace-ring-chunks", "page-placement",
        ],
        "table3" | "fig9" | "fig10" | "fig11" => SUITE,
        // fig12 sweeps a *list* of core counts and, by default, every
        // scheduler; --sched narrows it to a comma list.
        "fig12" => &[
            "scale", "datasets", "impl", "cores", "sched", "engine", "artifacts", "mtx-dir",
            "out-dir", "sockets", "replay-shards", "trace-ring-chunks", "page-placement",
        ],
        "run" => &[
            "dataset", "impl", "scale", "engine", "artifacts", "mtx-dir", "cores", "sched",
            "sockets", "replay-shards", "trace-ring-chunks", "page-placement",
        ],
        // mem runs one multi-core job and renders the shared-memory report
        // (per-core LLC/coherence/queueing + DRAM channel occupancy).
        "mem" => &[
            "dataset", "impl", "scale", "engine", "artifacts", "mtx-dir", "cores", "sched",
            "channels", "sockets", "replay-shards", "trace-ring-chunks", "page-placement",
            "out-dir",
        ],
        // ablate sweeps are engine-independent (hardwired NativeEngine).
        "ablate" => &["dataset", "scale", "mtx-dir", "out-dir"],
        "gen" => &["dataset", "out", "scale"],
        "table4" => &["out-dir"],
        // serve-demo drives the multi-tenant service layer: N tenants x M
        // jobs against one SimService, fairness/throughput report out.
        "serve-demo" => &[
            "tenants", "jobs", "workers", "depth", "backpressure", "weights", "dataset", "impl",
            "scale", "cores", "sched", "engine", "artifacts", "mtx-dir", "out-dir",
            "replay-shards", "trace-ring-chunks", "page-placement",
        ],
        _ => &[],
    }
}

/// Boolean flags each command accepts, validated like value options so an
/// inapplicable flag (e.g. `table4 --json`) errors instead of doing nothing.
fn allowed_flags(cmd: &str) -> &'static [&'static str] {
    match cmd {
        "table3" | "fig8" | "fig9" | "fig10" | "fig11" | "all" => &["verify", "quiet", "json"],
        "fig12" => &["quiet"],
        "run" => &["verify", "json"],
        "mem" => &["quiet"],
        "ablate" => &["quiet"],
        "table4" => &["sweep", "quiet"],
        "serve-demo" => &["verify", "quiet"],
        _ => &[],
    }
}

fn print_help() {
    println!(
        "spz — SparseZipper reproduction\n\
         commands: table3 fig4 fig8 fig9 fig10 fig11 fig12 table4 all run mem ablate isa config \
         gen help\n\
         suite commands (table3 fig8 fig9 fig10 fig11 all):\n\
         \x20   --scale F --threads N --datasets a,b --engine native|xla\n\
         \x20   --mtx-dir DIR --out-dir DIR --artifacts DIR --verify --quiet --json\n\
         \x20   --cores N --sched static|work-stealing|ws-dyn|ws-bw|ws-numa|ws-adapt (simulated\n\
         \x20   multi-core) --sockets N (NUMA sockets; channels split into per-socket groups)\n\
         \x20   --replay-shards N (parallel deterministic replay; power of two, results\n\
         \x20   bit-identical at any value) --trace-ring-chunks N (resident 64KB trace\n\
         \x20   chunks per core, 0=unbounded, >=2 spills overflow to disk; bit-identical\n\
         \x20   at any ring) --page-placement first-touch|interleave (NUMA page homes:\n\
         \x20   first toucher's socket vs blind line striping; identical at 1 socket)\n\
         \x20   (fig8 and all also take --impls a,b)\n\
         run:    --dataset NAME [--impl NAME] [--scale F] [--engine native|xla]\n\
         \x20       [--mtx-dir DIR] [--artifacts DIR] [--cores N] [--sched S] [--sockets N]\n\
         \x20       [--replay-shards N] [--trace-ring-chunks N] [--verify] [--json]\n\
         mem:    --dataset NAME [--impl NAME] [--cores N] [--sched S] [--channels N]\n\
         \x20       [--sockets N] [--replay-shards N] [--trace-ring-chunks N] [--scale F]\n\
         \x20       [--mtx-dir DIR] [--out-dir DIR] [--quiet]\n\
         \x20       (shared-memory report: per-core LLC/coherence/queueing + banked DRAM\n\
         \x20        channels + NUMA remote traffic + iterative-replay convergence)\n\
         fig12:  [--impl NAME] [--cores 1,2,4,8] [--sched a,b] [--sockets N]\n\
         \x20       [--replay-shards N] [--trace-ring-chunks N] [--scale F]\n\
         \x20       [--datasets a,b] [--engine E] [--mtx-dir DIR] [--out-dir DIR] [--quiet]\n\
         ablate: [--dataset NAME] [--scale F] [--mtx-dir DIR] [--out-dir DIR] [--quiet]\n\
         gen:    --dataset NAME --out FILE.mtx [--scale F]\n\
         table4: [--sweep] [--out-dir DIR] [--quiet]\n\
         serve-demo: [--tenants N] [--jobs M] [--workers N] [--depth N]\n\
         \x20       [--backpressure reject|block] [--weights 1,2,4] [--dataset NAME]\n\
         \x20       [--impl NAME] [--scale F] [--cores N] [--sched S] [--verify]\n\
         \x20       [--mtx-dir DIR] [--out-dir DIR] [--quiet]\n\
         \x20       (multi-tenant service demo: N tenant threads x M jobs through one\n\
         \x20        SimService; deterministic fairness report + bit-identity check)"
    );
}

fn session_config(a: &Args) -> Result<SessionConfig> {
    let mut cfg = SessionConfig::default();
    if let Some(e) = a.opts.get("engine") {
        cfg.engine = e.parse::<Engine>().map_err(anyhow::Error::msg)?;
    }
    if let Some(ad) = a.opts.get("artifacts") {
        cfg.artifact_dir = PathBuf::from(ad);
    }
    // --channels is a mem-only option (allowed_opts gates it), handled here
    // so the sockets/channels *combination* is validated once, after both
    // overrides: `--sockets 3 --channels 6` is a valid topology even though
    // 3 does not divide the default 4 channels.
    if let Some(chs) = a.opts.get("channels") {
        let n: usize = chs.parse().context("--channels")?;
        anyhow::ensure!(n >= 1, "--channels must be at least 1");
        cfg.sys.shared.dram_channels = n;
    }
    if let Some(s) = a.opts.get("sockets") {
        cfg.sys.shared.sockets = s.parse().context("--sockets")?;
    }
    // --replay-shards parallelizes the deterministic replay; results are
    // bit-identical at any value (a pure wall-clock knob, which is why it
    // never appears in the JSON exports).
    if let Some(s) = a.opts.get("replay-shards") {
        cfg.sys.shared.replay_shards = s.parse().context("--replay-shards")?;
    }
    // --trace-ring-chunks bounds the resident trace footprint per core
    // (overflow chunks spill to a temp file); like --replay-shards it is a
    // pure footprint knob — results are bit-identical at any ring size, and
    // the ring-dependent counters are zeroed in the stable JSON.
    if let Some(s) = a.opts.get("trace-ring-chunks") {
        cfg.sys.shared.trace_ring_chunks = s.parse().context("--trace-ring-chunks")?;
    }
    // --page-placement picks the DRAM page-to-socket policy; first-touch
    // (the default) is bit-identical to the blind interleave at 1 socket.
    if let Some(s) = a.opts.get("page-placement") {
        cfg.sys.shared.page_placement =
            sparsezipper::config::PagePlacement::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "--page-placement must be `first-touch` or `interleave`, got `{s}`"
                )
            })?;
    }
    if ["sockets", "channels", "replay-shards", "trace-ring-chunks", "page-placement"]
        .iter()
        .any(|k| a.opts.contains_key(*k))
    {
        // Validate at the argv boundary (like --cores) so a bad topology or
        // shard count is a clean CLI error, not a deep replay panic.
        cfg.sys.shared.validate()?;
    }
    Ok(cfg)
}

fn mtx_dir(a: &Args) -> Option<PathBuf> {
    a.opts.get("mtx-dir").map(PathBuf::from)
}

fn scale_opt(a: &Args) -> Result<Option<f64>> {
    a.opts.get("scale").map(|s| s.parse().context("--scale")).transpose()
}

fn parse_impls(spec: &str) -> Result<Vec<ImplId>> {
    spec.split(',')
        .map(|t| t.trim().parse::<ImplId>().map_err(anyhow::Error::msg))
        .collect()
}

fn parse_datasets(spec: &str, mtx: Option<&Path>) -> Result<Vec<DatasetSource>> {
    spec.split(',')
        .map(|t| DatasetSource::parse(t.trim(), mtx))
        .collect()
}

fn cores_opt(a: &Args) -> Result<Option<usize>> {
    match a.opts.get("cores") {
        Some(c) => {
            let n: usize = c.parse().context("--cores")?;
            anyhow::ensure!(
                (1..=64).contains(&n),
                "--cores must be between 1 and 64 (the shared-memory model \
                 supports up to 64 cores)"
            );
            Ok(Some(n))
        }
        None => Ok(None),
    }
}

fn sched_opt(a: &Args) -> Result<Option<Scheduler>> {
    a.opts
        .get("sched")
        .map(|s| s.parse::<Scheduler>().map_err(anyhow::Error::msg))
        .transpose()
}

/// fig12's `--sched a,b` comma list: parsed through the one
/// `Scheduler::from_str`, duplicates dropped (first occurrence wins) so a
/// repeated name cannot silently double the sweep.
fn parse_scheds(spec: &str) -> Result<Vec<Scheduler>> {
    let mut out: Vec<Scheduler> = Vec::new();
    for t in spec.split(',') {
        let s = t.trim().parse::<Scheduler>().map_err(anyhow::Error::msg)?;
        if !out.contains(&s) {
            out.push(s);
        }
    }
    Ok(out)
}

fn suite_spec(a: &Args) -> Result<SuiteSpec> {
    let mut spec = SuiteSpec::default();
    if let Some(s) = scale_opt(a)? {
        spec.scale = s;
    }
    if let Some(t) = a.opts.get("threads") {
        let n: usize = t.parse().context("--threads")?;
        // No silent clamping: a nonsensical thread count is an argv error
        // (the library rejects 0 too; catching it here names the flag).
        anyhow::ensure!(n >= 1, "--threads must be at least 1 (got {n})");
        spec.threads = n;
    }
    if let Some(c) = cores_opt(a)? {
        spec.cores = c;
    }
    if let Some(s) = sched_opt(a)? {
        // A scheduler choice on a serial run would be silently discarded;
        // reject it like any other inapplicable option.
        anyhow::ensure!(
            spec.cores >= 2,
            "--sched requires --cores >= 2 (it only affects multi-core runs)"
        );
        spec.sched = s;
    }
    let mtx = mtx_dir(a);
    if let Some(d) = a.opts.get("datasets") {
        spec.datasets = parse_datasets(d, mtx.as_deref())?;
    } else if let Some(dir) = &mtx {
        // Default registry names still honour --mtx-dir overrides.
        spec.datasets = registry::DATASETS
            .iter()
            .map(|d| DatasetSource::parse(d.name, Some(dir.as_path())))
            .collect::<Result<_>>()?;
    }
    if let Some(i) = a.opts.get("impls") {
        spec.impls = parse_impls(i)?;
    }
    spec.verify = a.flags.contains("verify");
    Ok(spec)
}

fn out_dir(a: &Args) -> PathBuf {
    a.opts
        .get("out-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("reports"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // `spz help` always prints help and exits 0, even with stray flags —
    // only unknown *commands* exit non-zero.
    if argv
        .first()
        .map(|c| matches!(c.as_str(), "help" | "--help" | "-h"))
        .unwrap_or(true)
    {
        print_help();
        return Ok(());
    }
    let a = parse_argv(&argv)?;
    let quiet = a.flags.contains("quiet");
    let json = a.flags.contains("json");
    match a.cmd.as_str() {
        "isa" => {
            print!("{}", sparsezipper::isa::instr::table1());
        }
        "fig4" => {
            println!("{}", sparsezipper::isa::codegen::fig4a_sort_kernel());
            println!("{}", sparsezipper::isa::codegen::fig4b_merge_kernel());
        }
        "config" => {
            print!("{}", sparsezipper::SystemConfig::default().table2());
        }
        "table4" => {
            let od = out_dir(&a);
            if a.flags.contains("sweep") {
                let mut s = String::new();
                for n in [4usize, 8, 16, 32] {
                    let m = AreaModel { n, num_regs: 16 };
                    s.push_str(&format!(
                        "N={n:<3} baseline {:>8.2} k um^2, spz {:>8.2} k um^2, overhead {:>5.2}%\n",
                        m.baseline_total(),
                        m.spz_total(),
                        m.overhead_pct()
                    ));
                }
                report::emit(&od, "table4_sweep.txt", &s, quiet)?;
            } else {
                report::emit(&od, "table4.txt", &AreaModel::paper().table4(), quiet)?;
            }
        }
        "table3" | "fig8" | "fig9" | "fig10" | "fig11" | "all" => {
            let session = Session::with_config(session_config(&a)?);
            let mut spec = suite_spec(&a)?;
            // table3 needs no simulation runs, only dataset characterization.
            if a.cmd == "table3" {
                spec.impls = vec![];
            } else if a.cmd == "fig10" {
                spec.impls = vec![ImplId::VecRadix, ImplId::Spz];
            } else if a.cmd == "fig11" {
                spec.impls = vec![ImplId::Spz, ImplId::SpzRsort];
            } else if a.cmd == "fig9" {
                spec.impls = vec![ImplId::VecRadix, ImplId::Spz, ImplId::SpzRsort];
            }
            eprintln!(
                "[spz] running suite: {} datasets x {} impls, scale {}, {} threads, engine {:?}",
                spec.datasets.len(),
                spec.impls.len(),
                spec.scale,
                spec.threads,
                session.engine()
            );
            let t0 = std::time::Instant::now();
            let r = session.run_suite(&spec)?;
            eprintln!("[spz] suite done in {:.1}s", t0.elapsed().as_secs_f64());
            let od = out_dir(&a);
            match a.cmd.as_str() {
                "table3" => report::emit(&od, "table3.txt", &figures::table3(&r), quiet)?,
                "fig8" => report::emit(&od, "fig8.txt", &figures::fig8(&r), quiet)?,
                "fig9" => report::emit(&od, "fig9.txt", &figures::fig9(&r), quiet)?,
                "fig10" => report::emit(&od, "fig10.txt", &figures::fig10(&r), quiet)?,
                "fig11" => report::emit(&od, "fig11.txt", &figures::fig11(&r), quiet)?,
                "all" => {
                    report::emit(&od, "table3.txt", &figures::table3(&r), quiet)?;
                    report::emit(&od, "fig8.txt", &figures::fig8(&r), quiet)?;
                    report::emit(&od, "fig9.txt", &figures::fig9(&r), quiet)?;
                    report::emit(&od, "fig10.txt", &figures::fig10(&r), quiet)?;
                    report::emit(&od, "fig11.txt", &figures::fig11(&r), quiet)?;
                    report::emit(&od, "table4.txt", &AreaModel::paper().table4(), quiet)?;
                    let mut shape = String::from("Qualitative shape checks (paper vs measured):\n");
                    for (name, ok) in figures::shape_checks(&r) {
                        shape.push_str(&format!("  [{}] {}\n", if ok { "ok" } else { "FAIL" }, name));
                    }
                    report::emit(&od, "shape_checks.txt", &shape, quiet)?;
                }
                _ => unreachable!(),
            }
            for (name, content) in figures::tsv_exports(&r) {
                report::emit(&od, &name, &content, true)?;
            }
            if json {
                report::emit(&od, "suite.json", &r.to_json(), true)?;
            }
        }
        "run" => {
            let session = Session::with_config(session_config(&a)?);
            let name = a.opts.get("dataset").context("--dataset required")?;
            let dataset = DatasetSource::parse(name, mtx_dir(&a).as_deref())?;
            let impl_id: ImplId = a
                .opts
                .get("impl")
                .map(|s| s.as_str())
                .unwrap_or("spz")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let mut job = JobSpec::new(impl_id, dataset.clone())
                .with_scale(scale_opt(&a)?.unwrap_or(1.0))
                .with_verify(a.flags.contains("verify"))
                .with_cores(cores_opt(&a)?.unwrap_or(1));
            if let Some(s) = sched_opt(&a)? {
                anyhow::ensure!(
                    job.cores >= 2,
                    "--sched requires --cores >= 2 (it only affects multi-core runs)"
                );
                job = job.with_scheduler(s);
            }
            let m = session.dataset(&dataset, job.scale)?;
            eprintln!(
                "[spz] {}: {} rows, {} nnz; running {impl_id} on {} core(s) (engine {:?})",
                dataset.name(),
                m.nrows,
                m.nnz(),
                job.cores,
                session.engine()
            );
            let res = session.run(&job)?;
            if json {
                println!("{}", res.to_json());
            } else {
                // `cycles` is the run's simulated wall-clock: the per-phase
                // critical path for multi-core runs, the core's cycles alone.
                print!(
                    "impl={} dataset={} cycles={:.0} l1d_accesses={} l1d_hit={:.1}% kv_pairs={} out_nnz={} verified={} wall={:.2}s",
                    res.impl_id,
                    res.dataset,
                    res.time_cycles(),
                    res.metrics.mem.l1d_accesses,
                    100.0 * res.metrics.mem.l1d_hit_rate(),
                    res.metrics.total_matrix_kv_pairs(),
                    res.out_nnz,
                    res.verified,
                    res.wall_secs
                );
                if let Some(mc) = &res.multicore {
                    print!(
                        " cores={} sched={} agg_cycles={:.0} efficiency={:.2}x imbalance={:.2}x",
                        res.cores,
                        res.sched.map(|s| s.name()).unwrap_or("-"),
                        mc.total.cycles,
                        mc.parallel_efficiency(),
                        mc.imbalance()
                    );
                }
                println!();
            }
        }
        "mem" => {
            // --channels and --sockets are folded in (and validated as a
            // combination) by session_config.
            let session = Session::with_config(session_config(&a)?);
            let name = a.opts.get("dataset").context("--dataset required")?;
            let dataset = DatasetSource::parse(name, mtx_dir(&a).as_deref())?;
            let impl_id: ImplId = a
                .opts
                .get("impl")
                .map(|s| s.as_str())
                .unwrap_or("spz")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let mut job = JobSpec::new(impl_id, dataset.clone())
                .with_scale(scale_opt(&a)?.unwrap_or(1.0))
                .with_cores(cores_opt(&a)?.unwrap_or(4));
            if let Some(s) = sched_opt(&a)? {
                anyhow::ensure!(
                    job.cores >= 2,
                    "--sched requires --cores >= 2 (it only affects multi-core runs)"
                );
                job = job.with_scheduler(s);
            }
            eprintln!(
                "[spz] shared-memory report: {impl_id} on {} at {} core(s), {} DRAM channel(s), \
                 {} socket(s)",
                dataset.name(),
                job.cores,
                session.system().shared.dram_channels,
                session.system().shared.sockets
            );
            let res = session.run(&job)?;
            report::emit(
                &out_dir(&a),
                &format!("mem_{}.txt", dataset.name()),
                &figures::mem_report(&res),
                quiet,
            )?;
        }
        "fig12" => {
            let session = Session::with_config(session_config(&a)?);
            let impl_id: ImplId = a
                .opts
                .get("impl")
                .map(|s| s.as_str())
                .unwrap_or("spz")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let mtx = mtx_dir(&a);
            let datasets: Vec<DatasetSource> = match a.opts.get("datasets") {
                Some(d) => parse_datasets(d, mtx.as_deref())?,
                None => registry::DATASETS
                    .iter()
                    .map(|d| DatasetSource::parse(d.name, mtx.as_deref()))
                    .collect::<Result<_>>()?,
            };
            let mut cores: Vec<usize> = match a.opts.get("cores") {
                Some(spec) => spec
                    .split(',')
                    .map(|t| t.trim().parse::<usize>().context("--cores"))
                    .collect::<Result<_>>()?,
                None => vec![1, 2, 4, 8],
            };
            anyhow::ensure!(
                cores.iter().all(|&c| (1..=64).contains(&c)),
                "--cores entries must be between 1 and 64"
            );
            cores.sort_unstable();
            cores.dedup();
            // One Scheduler::from_str serves run/suite/mem and this list,
            // so a new scheduler name works everywhere at once. The default
            // sweep drops ws-numa at one socket: it is bit-identical to
            // ws-bw there (pinned by tests), so its rows would only repeat
            // ws-bw's. An explicit --sched list is taken as given.
            let scheds: Vec<Scheduler> = match a.opts.get("sched") {
                Some(spec) => parse_scheds(spec)?,
                None => Scheduler::ALL
                    .into_iter()
                    .filter(|&s| {
                        s != Scheduler::WorkStealingNuma
                            || session.system().shared.sockets >= 2
                    })
                    .collect(),
            };
            let scale = scale_opt(&a)?.unwrap_or(1.0);
            eprintln!(
                "[spz] fig12 scaling: {impl_id} on {} datasets at cores {:?}, scale {scale}, \
                 schedulers {:?}",
                datasets.len(),
                cores,
                scheds.iter().map(|s| s.name()).collect::<Vec<_>>()
            );
            let t0 = std::time::Instant::now();
            let points =
                figures::scaling_sweep(&session, &datasets, impl_id, scale, &cores, &scheds)?;
            eprintln!("[spz] scaling sweep done in {:.1}s", t0.elapsed().as_secs_f64());
            let od = out_dir(&a);
            report::emit(&od, "fig12_scaling.txt", &figures::fig12(&points), quiet)?;
            report::emit(&od, "fig12.tsv", &figures::fig12_tsv(&points), true)?;
        }
        "ablate" => {
            use sparsezipper::coordinator::ablate;
            let session = Session::with_config(session_config(&a)?);
            let spec = a.opts.get("dataset").map(|s| s.as_str()).unwrap_or("p2p");
            let dataset = DatasetSource::parse(spec, mtx_dir(&a).as_deref())?;
            // Report under the dataset's display name (path specs would
            // otherwise produce a nested, unwritable filename).
            let name = dataset.name();
            let m = session.dataset(&dataset, scale_opt(&a)?.unwrap_or(1.0))?;
            eprintln!("[spz] ablations on {name} ({} rows, {} nnz)", m.nrows, m.nnz());
            let mut s = String::new();
            s.push_str(&ablate::render(
                &format!("Systolic array size sweep ({name})"),
                &ablate::array_size_sweep(&m, &[4, 8, 16, 32])?,
            ));
            s.push_str(&ablate::render(
                &format!("Non-speculative issue overhead sweep ({name})"),
                &ablate::issue_overhead_sweep(&m, &[0, 4, 16, 64])?,
            ));
            s.push_str(&ablate::render(
                &format!("vec-radix ESC block-size sweep ({name})"),
                &ablate::block_size_sweep(&m, &[1024, 4096, 16384, 65536, 262144])?,
            ));
            report::emit(&out_dir(&a), &format!("ablate_{name}.txt"), &s, quiet)?;
        }
        "serve-demo" => {
            use sparsezipper::coordinator::demo;
            use sparsezipper::service::Backpressure;
            let parse_u = |key: &str, default: usize| -> Result<usize> {
                match a.opts.get(key) {
                    Some(v) => {
                        let n: usize = v.parse().with_context(|| format!("--{key}"))?;
                        anyhow::ensure!(n >= 1, "--{key} must be at least 1 (got {n})");
                        Ok(n)
                    }
                    None => Ok(default),
                }
            };
            let tenants = parse_u("tenants", 4)?;
            let jobs = parse_u("jobs", 16)?;
            let workers = parse_u(
                "workers",
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            )?;
            let depth = parse_u("depth", 64)?;
            let backpressure = match a.opts.get("backpressure") {
                Some(b) => b.parse::<Backpressure>()?,
                None => Backpressure::Block,
            };
            let weights: Vec<u32> = match a.opts.get("weights") {
                Some(w) => w
                    .split(',')
                    .map(|t| t.trim().parse::<u32>().context("--weights"))
                    .collect::<Result<_>>()?,
                None => vec![1],
            };
            anyhow::ensure!(
                !weights.is_empty() && weights.iter().all(|&w| w >= 1),
                "--weights entries must be at least 1"
            );
            let dataset = DatasetSource::parse(
                a.opts.get("dataset").map(|s| s.as_str()).unwrap_or("p2p"),
                mtx_dir(&a).as_deref(),
            )?;
            let impl_id: ImplId = a
                .opts
                .get("impl")
                .map(|s| s.as_str())
                .unwrap_or("spz")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let mut job = JobSpec::new(impl_id, dataset)
                .with_scale(scale_opt(&a)?.unwrap_or(0.05))
                .with_verify(a.flags.contains("verify"))
                .with_cores(cores_opt(&a)?.unwrap_or(1));
            if let Some(s) = sched_opt(&a)? {
                anyhow::ensure!(
                    job.cores >= 2,
                    "--sched requires --cores >= 2 (it only affects multi-core runs)"
                );
                job = job.with_scheduler(s);
            }
            eprintln!(
                "[spz] serve-demo: {tenants} tenants x {jobs} jobs, {workers} workers, \
                 queue depth {depth}"
            );
            let rep = demo::serve_demo(
                session_config(&a)?,
                &demo::DemoConfig { tenants, jobs, workers, depth, backpressure, weights, job },
            )?;
            report::emit(&out_dir(&a), "serve_demo.txt", &rep, quiet)?;
        }
        "gen" => {
            let name = a.opts.get("dataset").context("--dataset required")?;
            let out = a.opts.get("out").context("--out required")?;
            let dataset = DatasetSource::registry(name)?;
            let m = dataset.build(scale_opt(&a)?.unwrap_or(1.0))?;
            sparsezipper::matrix::mm::write_mtx(Path::new(out), &m)?;
            println!("wrote {} ({} rows, {} nnz)", out, m.nrows, m.nnz());
        }
        other => bail!("unknown command '{other}' (try: spz help)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn duplicate_value_opt_rejected() {
        let e = parse_argv(&v(&["run", "--scale", "0.1", "--scale", "0.2"])).unwrap_err();
        assert!(e.to_string().contains("duplicate option --scale"), "{e}");
    }

    #[test]
    fn missing_value_rejected() {
        let e = parse_argv(&v(&["run", "--scale"])).unwrap_err();
        assert!(e.to_string().contains("--scale needs a value"), "{e}");
    }

    #[test]
    fn flags_and_opts_parse() {
        let a = parse_argv(&v(&["run", "--verify", "--json", "--impl", "spz"])).unwrap();
        assert_eq!(a.cmd, "run");
        assert!(a.flags.contains("verify") && a.flags.contains("json"));
        assert_eq!(a.opts.get("impl").unwrap(), "spz");
    }

    #[test]
    fn repeated_boolean_flag_is_idempotent() {
        let a = parse_argv(&v(&["all", "--verify", "--verify"])).unwrap();
        assert!(a.flags.contains("verify"));
    }

    #[test]
    fn positional_rejected() {
        assert!(parse_argv(&v(&["run", "stray"])).is_err());
    }

    #[test]
    fn unknown_or_misplaced_option_rejected() {
        let e = parse_argv(&v(&["all", "--scal", "0.01"])).unwrap_err();
        assert!(e.to_string().contains("unknown option --scal"), "{e}");
        // `--impl` (singular) is a `run` option, not a suite option.
        let e = parse_argv(&v(&["fig8", "--impl", "spz"])).unwrap_err();
        assert!(e.to_string().contains("unknown option --impl for 'fig8'"), "{e}");
        // ...but is fine where it belongs.
        assert!(parse_argv(&v(&["run", "--impl", "spz"])).is_ok());
    }

    #[test]
    fn typoed_command_reported_as_command_error() {
        let e = parse_argv(&v(&["tabel3", "--scale", "0.1"])).unwrap_err();
        assert!(e.to_string().contains("unknown command 'tabel3'"), "{e}");
    }

    #[test]
    fn inapplicable_flag_rejected() {
        let e = parse_argv(&v(&["table4", "--json"])).unwrap_err();
        assert!(e.to_string().contains("--json does not apply to 'table4'"), "{e}");
        assert!(parse_argv(&v(&["gen", "--verify", "--dataset", "p2p", "--out", "x.mtx"])).is_err());
        assert!(parse_argv(&v(&["table4", "--sweep", "--quiet"])).is_ok());
    }

    #[test]
    fn suite_spec_parses_typed_lists() {
        let a = parse_argv(&v(&[
            "fig8", "--datasets", "p2p,wiki", "--impls", "spz,scl-hash", "--scale", "0.1",
        ]))
        .unwrap();
        let spec = suite_spec(&a).unwrap();
        assert_eq!(spec.datasets.len(), 2);
        assert_eq!(spec.impls, vec![ImplId::Spz, ImplId::SclHash]);
        assert!((spec.scale - 0.1).abs() < 1e-12);
    }

    #[test]
    fn cores_and_sched_parse() {
        let a = parse_argv(&v(&["run", "--cores", "8", "--sched", "static"])).unwrap();
        assert_eq!(cores_opt(&a).unwrap(), Some(8));
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::Static));
        let a = parse_argv(&v(&["fig8", "--cores", "4", "--sched", "work-stealing"])).unwrap();
        let spec = suite_spec(&a).unwrap();
        assert_eq!(spec.cores, 4);
        assert_eq!(spec.sched, Scheduler::WorkStealing);
        let a = parse_argv(&v(&["run", "--cores", "0"])).unwrap();
        assert!(cores_opt(&a).unwrap_err().to_string().contains("between 1 and 64"));
        let a = parse_argv(&v(&["run", "--cores", "65"])).unwrap();
        assert!(cores_opt(&a).unwrap_err().to_string().contains("between 1 and 64"));
        let a = parse_argv(&v(&["run", "--sched", "greedy"])).unwrap();
        let e = sched_opt(&a).unwrap_err().to_string();
        assert!(e.contains("static") && e.contains("greedy"), "{e}");
        // --sched on a serial suite would be silently discarded -> error.
        let a = parse_argv(&v(&["fig8", "--sched", "static"])).unwrap();
        let e = suite_spec(&a).unwrap_err().to_string();
        assert!(e.contains("--sched requires --cores"), "{e}");
        // fig12 parses its own --cores list; suite-only options don't apply.
        assert!(parse_argv(&v(&["fig12", "--cores", "1,2,4", "--impl", "spz"])).is_ok());
        assert!(parse_argv(&v(&["fig12", "--threads", "2"])).is_err());
    }

    #[test]
    fn mem_command_parses_its_options() {
        let a = parse_argv(&v(&[
            "mem", "--dataset", "p2p", "--cores", "8", "--sched", "ws-dyn", "--channels", "2",
            "--quiet",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "mem");
        assert_eq!(a.opts.get("channels").unwrap(), "2");
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingDyn));
        // --channels belongs to mem, not run.
        let e = parse_argv(&v(&["run", "--channels", "2"])).unwrap_err();
        assert!(e.to_string().contains("unknown option --channels"), "{e}");
        // --json does not apply to mem.
        assert!(parse_argv(&v(&["mem", "--dataset", "p2p", "--json"])).is_err());
    }

    #[test]
    fn ws_dyn_sched_accepted_by_suite_commands() {
        let a = parse_argv(&v(&["fig8", "--cores", "4", "--sched", "ws-dyn"])).unwrap();
        let spec = suite_spec(&a).unwrap();
        assert_eq!(spec.sched, Scheduler::WorkStealingDyn);
    }

    #[test]
    fn ws_bw_lands_in_every_command_via_the_one_parser() {
        // One Scheduler::from_str feeds run, the suites, mem, and fig12:
        // the bandwidth-aware scheduler parses identically everywhere.
        let a = parse_argv(&v(&["run", "--cores", "4", "--sched", "ws-bw"])).unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingBw));
        let a = parse_argv(&v(&["fig8", "--cores", "4", "--sched", "ws-bw"])).unwrap();
        assert_eq!(suite_spec(&a).unwrap().sched, Scheduler::WorkStealingBw);
        let a = parse_argv(&v(&["mem", "--dataset", "p2p", "--sched", "ws-bw", "--cores", "2"]))
            .unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingBw));
        // fig12 takes a comma list through the same parser; duplicates are
        // dropped so a repeated name cannot double the sweep.
        assert!(parse_argv(&v(&["fig12", "--sched", "ws-dyn,ws-bw"])).is_ok());
        assert_eq!(
            parse_scheds("ws-dyn, ws-bw").unwrap(),
            vec![Scheduler::WorkStealingDyn, Scheduler::WorkStealingBw]
        );
        assert_eq!(
            parse_scheds("ws-bw,ws-bw,static").unwrap(),
            vec![Scheduler::WorkStealingBw, Scheduler::Static]
        );
        assert!(parse_scheds("ws-bw,greedy").is_err());
    }

    #[test]
    fn sockets_option_parses_and_validates() {
        // --sockets is accepted wherever --cores is, feeding the session's
        // SharedMemConfig through the one session_config path.
        for cmd in [
            vec!["run", "--sockets", "2"],
            vec!["mem", "--dataset", "p2p", "--sockets", "2"],
            vec!["fig12", "--sockets", "2"],
            vec!["fig8", "--sockets", "2"],
        ] {
            let a = parse_argv(&v(&cmd)).unwrap();
            let cfg = session_config(&a).unwrap();
            assert_eq!(cfg.sys.shared.sockets, 2, "{cmd:?}");
        }
        // A topology the channels cannot tile is a clean argv-boundary error.
        let a = parse_argv(&v(&["run", "--sockets", "3"])).unwrap();
        let e = format!("{:#}", session_config(&a).unwrap_err());
        assert!(e.contains("sockets"), "{e}");
        let a = parse_argv(&v(&["run", "--sockets", "0"])).unwrap();
        assert!(session_config(&a).is_err());
        // The sockets/channels *combination* is what validates: 3 sockets
        // are fine once mem's --channels makes the groups tile.
        let a = parse_argv(&v(&[
            "mem", "--dataset", "p2p", "--sockets", "3", "--channels", "6",
        ]))
        .unwrap();
        let cfg = session_config(&a).unwrap();
        assert_eq!(cfg.sys.shared.sockets, 3);
        assert_eq!(cfg.sys.shared.dram_channels, 6);
        let a = parse_argv(&v(&[
            "mem", "--dataset", "p2p", "--sockets", "2", "--channels", "3",
        ]))
        .unwrap();
        assert!(session_config(&a).is_err(), "3 channels cannot split across 2 sockets");
        // gen/table4 do not take --sockets.
        assert!(parse_argv(&v(&["gen", "--sockets", "2"])).is_err());
    }

    #[test]
    fn replay_shards_option_parses_and_validates() {
        // --replay-shards rides the same session_config path as --sockets:
        // accepted by every command that runs the replay, validated (not
        // clamped) at the argv boundary.
        for cmd in [
            vec!["run", "--replay-shards", "8"],
            vec!["mem", "--dataset", "p2p", "--replay-shards", "8"],
            vec!["fig12", "--replay-shards", "8"],
            vec!["fig8", "--replay-shards", "8"],
            vec!["serve-demo", "--replay-shards", "8"],
        ] {
            let a = parse_argv(&v(&cmd)).unwrap();
            let cfg = session_config(&a).unwrap();
            assert_eq!(cfg.sys.shared.replay_shards, 8, "{cmd:?}");
        }
        // Zero and non-power-of-two shard counts are clean CLI errors.
        for bad in ["0", "3", "128"] {
            let a = parse_argv(&v(&["run", "--replay-shards", bad])).unwrap();
            let e = format!("{:#}", session_config(&a).unwrap_err());
            assert!(e.contains("replay_shards"), "--replay-shards {bad}: {e}");
        }
        // gen/table4 never replay, so they do not take the knob.
        assert!(parse_argv(&v(&["gen", "--replay-shards", "4"])).is_err());
        assert!(parse_argv(&v(&["table4", "--replay-shards", "4"])).is_err());
    }

    #[test]
    fn trace_ring_chunks_option_parses_and_validates() {
        // --trace-ring-chunks rides the same session_config path as
        // --replay-shards: accepted wherever the replay runs, validated (not
        // clamped) at the argv boundary.
        for cmd in [
            vec!["run", "--trace-ring-chunks", "4"],
            vec!["mem", "--dataset", "p2p", "--trace-ring-chunks", "4"],
            vec!["fig12", "--trace-ring-chunks", "4"],
            vec!["fig8", "--trace-ring-chunks", "4"],
            vec!["serve-demo", "--trace-ring-chunks", "4"],
        ] {
            let a = parse_argv(&v(&cmd)).unwrap();
            let cfg = session_config(&a).unwrap();
            assert_eq!(cfg.sys.shared.trace_ring_chunks, 4, "{cmd:?}");
        }
        // 0 (unbounded) and any ring >= 2 are fine; exactly 1 is a clean
        // CLI error, never a silent clamp.
        for ok in ["0", "2", "1024"] {
            let a = parse_argv(&v(&["run", "--trace-ring-chunks", ok])).unwrap();
            assert!(session_config(&a).is_ok(), "--trace-ring-chunks {ok}");
        }
        let a = parse_argv(&v(&["run", "--trace-ring-chunks", "1"])).unwrap();
        let e = format!("{:#}", session_config(&a).unwrap_err());
        assert!(e.contains("trace_ring_chunks"), "{e}");
        // gen/table4 never replay, so they do not take the knob.
        assert!(parse_argv(&v(&["gen", "--trace-ring-chunks", "4"])).is_err());
        assert!(parse_argv(&v(&["table4", "--trace-ring-chunks", "4"])).is_err());
    }

    #[test]
    fn page_placement_option_parses_and_validates() {
        // --page-placement rides the same session_config path as the other
        // replay knobs: accepted wherever the replay runs, both policy names
        // parsed, bad names a clean CLI error.
        use sparsezipper::config::PagePlacement;
        for cmd in [
            vec!["run", "--page-placement", "interleave"],
            vec!["mem", "--dataset", "p2p", "--page-placement", "interleave"],
            vec!["fig12", "--page-placement", "interleave"],
            vec!["fig8", "--page-placement", "interleave"],
            vec!["serve-demo", "--page-placement", "interleave"],
        ] {
            let a = parse_argv(&v(&cmd)).unwrap();
            let cfg = session_config(&a).unwrap();
            assert_eq!(cfg.sys.shared.page_placement, PagePlacement::Interleave, "{cmd:?}");
        }
        // First-touch is the default and also spells explicitly.
        let a = parse_argv(&v(&["run"])).unwrap();
        assert_eq!(
            session_config(&a).unwrap().sys.shared.page_placement,
            PagePlacement::FirstTouch
        );
        let a = parse_argv(&v(&["run", "--page-placement", "first-touch"])).unwrap();
        assert_eq!(
            session_config(&a).unwrap().sys.shared.page_placement,
            PagePlacement::FirstTouch
        );
        let a = parse_argv(&v(&["run", "--page-placement", "random"])).unwrap();
        let e = format!("{:#}", session_config(&a).unwrap_err());
        assert!(e.contains("page-placement"), "{e}");
        // gen/table4 never replay, so they do not take the knob.
        assert!(parse_argv(&v(&["gen", "--page-placement", "interleave"])).is_err());
        assert!(parse_argv(&v(&["table4", "--page-placement", "interleave"])).is_err());
    }

    #[test]
    fn ws_adapt_parses_like_every_other_scheduler() {
        // run / suites / mem / fig12 / serve-demo all go through the same
        // two parsers (sched_opt + parse_scheds), so the adaptive scheduler
        // lands everywhere at once.
        let a = parse_argv(&v(&["run", "--cores", "4", "--sched", "ws-adapt"])).unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingAdapt));
        let a = parse_argv(&v(&["fig8", "--cores", "4", "--sched", "ws-adapt"])).unwrap();
        assert_eq!(suite_spec(&a).unwrap().sched, Scheduler::WorkStealingAdapt);
        let a = parse_argv(&v(&[
            "mem", "--dataset", "p2p", "--sched", "ws-adapt", "--cores", "2",
        ]))
        .unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingAdapt));
        let a = parse_argv(&v(&[
            "serve-demo", "--cores", "2", "--sched", "ws-adapt",
        ]))
        .unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingAdapt));
        assert_eq!(
            parse_scheds("ws-numa,ws-adapt").unwrap(),
            vec![Scheduler::WorkStealingNuma, Scheduler::WorkStealingAdapt]
        );
        // The fig12 default sweep includes ws-adapt via Scheduler::ALL.
        assert!(Scheduler::ALL.contains(&Scheduler::WorkStealingAdapt));
    }

    #[test]
    fn ws_numa_parses_like_every_other_scheduler() {
        let a = parse_argv(&v(&["run", "--cores", "4", "--sched", "ws-numa"])).unwrap();
        assert_eq!(sched_opt(&a).unwrap(), Some(Scheduler::WorkStealingNuma));
        let a = parse_argv(&v(&["fig8", "--cores", "4", "--sched", "ws-numa"])).unwrap();
        assert_eq!(suite_spec(&a).unwrap().sched, Scheduler::WorkStealingNuma);
        assert_eq!(
            parse_scheds("ws-bw,ws-numa").unwrap(),
            vec![Scheduler::WorkStealingBw, Scheduler::WorkStealingNuma]
        );
    }

    #[test]
    fn zero_threads_is_an_argv_error_not_a_clamp() {
        let a = parse_argv(&v(&["fig8", "--threads", "0"])).unwrap();
        let e = suite_spec(&a).unwrap_err().to_string();
        assert!(e.contains("--threads must be at least 1"), "{e}");
        let a = parse_argv(&v(&["fig8", "--threads", "3"])).unwrap();
        assert_eq!(suite_spec(&a).unwrap().threads, 3);
    }

    #[test]
    fn serve_demo_parses_its_options() {
        let a = parse_argv(&v(&[
            "serve-demo",
            "--tenants",
            "4",
            "--jobs",
            "64",
            "--workers",
            "2",
            "--depth",
            "8",
            "--backpressure",
            "reject",
            "--weights",
            "1,2,4",
            "--quiet",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "serve-demo");
        assert_eq!(a.opts.get("tenants").unwrap(), "4");
        assert_eq!(a.opts.get("backpressure").unwrap(), "reject");
        assert!(a.flags.contains("quiet"));
        // serve-demo has no --threads (the pool is sized by --workers) and
        // no --json.
        assert!(parse_argv(&v(&["serve-demo", "--threads", "2"])).is_err());
        assert!(parse_argv(&v(&["serve-demo", "--json"])).is_err());
        // --tenants belongs to serve-demo only.
        assert!(parse_argv(&v(&["fig8", "--tenants", "2"])).is_err());
    }

    #[test]
    fn bad_impl_is_actionable() {
        let a = parse_argv(&v(&["fig8", "--impls", "warp-drive"])).unwrap();
        let e = suite_spec(&a).unwrap_err().to_string();
        assert!(e.contains("unknown implementation 'warp-drive'"), "{e}");
        assert!(e.contains("scl-array"), "{e}");
    }
}
