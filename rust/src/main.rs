//! `spz` — SparseZipper reproduction CLI (hand-rolled arg parsing; the
//! offline vendor set has no clap).
//!
//! ```text
//! spz table3|fig8|fig9|fig10|fig11|table4|all [--scale F] [--threads N]
//!     [--datasets a,b,...] [--impls a,b,...] [--engine native|xla]
//!     [--verify] [--out-dir DIR] [--mtx-dir DIR]
//! spz run --dataset NAME --impl NAME [--scale F] [--engine native|xla]
//! spz isa | config | gen --dataset NAME --out FILE.mtx [--scale F]
//! ```

use anyhow::{bail, Context, Result};
use sparsezipper::area::AreaModel;
use sparsezipper::coordinator::{figures, report, run_suite, SuiteConfig};
use sparsezipper::matrix::registry;
use sparsezipper::runtime::Engine;
use sparsezipper::spgemm;
use std::path::PathBuf;

struct Args {
    cmd: String,
    opts: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut opts = std::collections::HashMap::new();
    let mut flags = std::collections::HashSet::new();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            // Peek: flag or key-value?
            match key {
                "verify" | "quiet" | "sweep" => {
                    flags.insert(key.to_string());
                }
                _ => {
                    let v = it.next().with_context(|| format!("--{key} needs a value"))?;
                    opts.insert(key.to_string(), v);
                }
            }
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(Args { cmd, opts, flags })
}

fn suite_config(a: &Args) -> Result<SuiteConfig> {
    let mut cfg = SuiteConfig::default();
    if let Some(s) = a.opts.get("scale") {
        cfg.scale = s.parse().context("--scale")?;
    }
    if let Some(t) = a.opts.get("threads") {
        cfg.threads = t.parse().context("--threads")?;
    }
    if let Some(d) = a.opts.get("datasets") {
        cfg.datasets = d.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(i) = a.opts.get("impls") {
        cfg.impls = i.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(e) = a.opts.get("engine") {
        cfg.engine = e.parse::<Engine>().map_err(anyhow::Error::msg)?;
    }
    if let Some(m) = a.opts.get("mtx-dir") {
        cfg.mtx_dir = Some(PathBuf::from(m));
    }
    if let Some(ad) = a.opts.get("artifacts") {
        cfg.artifact_dir = PathBuf::from(ad);
    }
    cfg.verify = a.flags.contains("verify");
    Ok(cfg)
}

fn out_dir(a: &Args) -> PathBuf {
    a.opts
        .get("out-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("reports"))
}

fn main() -> Result<()> {
    let a = parse_args()?;
    let quiet = a.flags.contains("quiet");
    match a.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!(
                "spz — SparseZipper reproduction\n\
                 commands: table3 fig4 fig8 fig9 fig10 fig11 table4 all run ablate isa config gen help\n\
                 common options: --scale F --threads N --datasets a,b --impls a,b\n\
                 \x20                --engine native|xla --verify --out-dir DIR --mtx-dir DIR"
            );
        }
        "isa" => {
            print!("{}", sparsezipper::isa::instr::table1());
        }
        "fig4" => {
            println!("{}", sparsezipper::isa::codegen::fig4a_sort_kernel());
            println!("{}", sparsezipper::isa::codegen::fig4b_merge_kernel());
        }
        "config" => {
            print!("{}", sparsezipper::SystemConfig::default().table2());
        }
        "table4" => {
            let od = out_dir(&a);
            if a.flags.contains("sweep") {
                let mut s = String::new();
                for n in [4usize, 8, 16, 32] {
                    let m = AreaModel { n, num_regs: 16 };
                    s.push_str(&format!(
                        "N={n:<3} baseline {:>8.2} k um^2, spz {:>8.2} k um^2, overhead {:>5.2}%\n",
                        m.baseline_total(),
                        m.spz_total(),
                        m.overhead_pct()
                    ));
                }
                report::emit(&od, "table4_sweep.txt", &s, quiet)?;
            } else {
                report::emit(&od, "table4.txt", &AreaModel::paper().table4(), quiet)?;
            }
        }
        "table3" | "fig8" | "fig9" | "fig10" | "fig11" | "all" => {
            let mut cfg = suite_config(&a)?;
            // table3 needs no simulation runs, only dataset characterization.
            if a.cmd == "table3" {
                cfg.impls = vec![];
            } else if a.cmd == "fig10" {
                cfg.impls = vec!["vec-radix".into(), "spz".into()];
            } else if a.cmd == "fig11" {
                cfg.impls = vec!["spz".into(), "spz-rsort".into()];
            } else if a.cmd == "fig9" {
                cfg.impls = vec!["vec-radix".into(), "spz".into(), "spz-rsort".into()];
            }
            eprintln!(
                "[spz] running suite: {} datasets x {} impls, scale {}, {} threads, engine {:?}",
                cfg.datasets.len(),
                cfg.impls.len(),
                cfg.scale,
                cfg.threads,
                cfg.engine
            );
            let t0 = std::time::Instant::now();
            let r = run_suite(&cfg)?;
            eprintln!("[spz] suite done in {:.1}s", t0.elapsed().as_secs_f64());
            let od = out_dir(&a);
            match a.cmd.as_str() {
                "table3" => report::emit(&od, "table3.txt", &figures::table3(&r), quiet)?,
                "fig8" => report::emit(&od, "fig8.txt", &figures::fig8(&r), quiet)?,
                "fig9" => report::emit(&od, "fig9.txt", &figures::fig9(&r), quiet)?,
                "fig10" => report::emit(&od, "fig10.txt", &figures::fig10(&r), quiet)?,
                "fig11" => report::emit(&od, "fig11.txt", &figures::fig11(&r), quiet)?,
                "all" => {
                    report::emit(&od, "table3.txt", &figures::table3(&r), quiet)?;
                    report::emit(&od, "fig8.txt", &figures::fig8(&r), quiet)?;
                    report::emit(&od, "fig9.txt", &figures::fig9(&r), quiet)?;
                    report::emit(&od, "fig10.txt", &figures::fig10(&r), quiet)?;
                    report::emit(&od, "fig11.txt", &figures::fig11(&r), quiet)?;
                    report::emit(&od, "table4.txt", &AreaModel::paper().table4(), quiet)?;
                    let mut shape = String::from("Qualitative shape checks (paper vs measured):\n");
                    for (name, ok) in figures::shape_checks(&r) {
                        shape.push_str(&format!("  [{}] {}\n", if ok { "ok" } else { "FAIL" }, name));
                    }
                    report::emit(&od, "shape_checks.txt", &shape, quiet)?;
                }
                _ => unreachable!(),
            }
            for (name, content) in figures::tsv_exports(&r) {
                report::emit(&od, &name, &content, true)?;
            }
        }
        "run" => {
            let cfg = suite_config(&a)?;
            let dataset = a.opts.get("dataset").context("--dataset required")?;
            let impl_name = a
                .opts
                .get("impl")
                .map(|s| s.as_str())
                .unwrap_or("spz");
            let m = sparsezipper::coordinator::runner::build_dataset(&cfg, dataset)?;
            eprintln!(
                "[spz] {dataset}: {} rows, {} nnz; running {impl_name} (engine {:?})",
                m.nrows,
                m.nnz(),
                cfg.engine
            );
            let reference = if cfg.verify {
                Some(spgemm::reference(&m, &m))
            } else {
                None
            };
            let res = sparsezipper::coordinator::run_one(
                impl_name,
                dataset,
                &m,
                cfg.sys,
                cfg.engine,
                &cfg.artifact_dir,
                reference.as_ref(),
            )?;
            println!(
                "impl={} dataset={} cycles={:.0} l1d_accesses={} l1d_hit={:.1}% kv_pairs={} out_nnz={} verified={} wall={:.2}s",
                res.impl_name,
                res.dataset,
                res.metrics.cycles,
                res.metrics.mem.l1d_accesses,
                100.0 * res.metrics.mem.l1d_hit_rate(),
                res.metrics.total_matrix_kv_pairs(),
                res.out_nnz,
                res.verified,
                res.wall_secs
            );
        }
        "ablate" => {
            use sparsezipper::coordinator::ablate;
            let cfg = suite_config(&a)?;
            let dataset = a.opts.get("dataset").map(|s| s.as_str()).unwrap_or("p2p");
            let m = sparsezipper::coordinator::runner::build_dataset(&cfg, dataset)?;
            eprintln!("[spz] ablations on {dataset} ({} rows, {} nnz)", m.nrows, m.nnz());
            let mut s = String::new();
            s.push_str(&ablate::render(
                &format!("Systolic array size sweep ({dataset})"),
                &ablate::array_size_sweep(&m, &[4, 8, 16, 32])?,
            ));
            s.push_str(&ablate::render(
                &format!("Non-speculative issue overhead sweep ({dataset})"),
                &ablate::issue_overhead_sweep(&m, &[0, 4, 16, 64])?,
            ));
            s.push_str(&ablate::render(
                &format!("vec-radix ESC block-size sweep ({dataset})"),
                &ablate::block_size_sweep(&m, &[1024, 4096, 16384, 65536, 262144])?,
            ));
            report::emit(&out_dir(&a), &format!("ablate_{dataset}.txt"), &s, quiet)?;
        }
        "gen" => {
            let cfg = suite_config(&a)?;
            let dataset = a.opts.get("dataset").context("--dataset required")?;
            let out = a.opts.get("out").context("--out required")?;
            let d = registry::find(dataset).context("unknown dataset")?;
            let m = d.build(cfg.scale);
            sparsezipper::matrix::mm::write_mtx(std::path::Path::new(out), &m)?;
            println!("wrote {} ({} rows, {} nnz)", out, m.nrows, m.nnz());
        }
        other => bail!("unknown command '{other}' (try: spz help)"),
    }
    Ok(())
}
