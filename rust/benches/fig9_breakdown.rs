//! Bench: Figure 9 regeneration — execution-time breakdown of vec-radix,
//! spz and spz-rsort per dataset.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::coordinator::{figures, run_suite, SuiteConfig};

fn main() {
    let cfg = SuiteConfig {
        scale: bench_util::scale(),
        impls: vec!["vec-radix".into(), "spz".into(), "spz-rsort".into()],
        ..Default::default()
    };
    println!("== Figure 9 (scale {}) ==", cfg.scale);
    let mut out = None;
    bench_util::bench("fig9 suite", 1, || {
        out = Some(run_suite(&cfg).expect("suite"));
    });
    println!("{}", figures::fig9(&out.unwrap()));
}
