//! Bench: Figure 9 regeneration — execution-time breakdown of vec-radix,
//! spz and spz-rsort per dataset.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{Session, SuiteSpec};
use sparsezipper::coordinator::figures;
use sparsezipper::ImplId;

fn main() {
    let session = Session::new();
    let spec = SuiteSpec {
        scale: bench_util::scale(),
        impls: vec![ImplId::VecRadix, ImplId::Spz, ImplId::SpzRsort],
        ..Default::default()
    };
    println!("== Figure 9 (scale {}) ==", spec.scale);
    let mut out = None;
    bench_util::bench("fig9 suite", 1, || {
        out = Some(session.run_suite(&spec).expect("suite"));
    });
    println!("{}", figures::fig9(&out.unwrap()));
}
