//! Bench: sharded replay wall-time — the `ReplayEngine` run in isolation
//! (no phase-1 simulation) over a deterministic synthetic coherence-heavy
//! trace set, at 1/2/4/8 shards. Every shard count is asserted bit-identical
//! to serial before it is timed, so a speedup can never be bought with a
//! results drift.
//!
//! `SPZ_BENCH_EVENTS` scales the per-core event count (default 300k);
//! `SPZ_BENCH_REPS` the repetitions. Medians land in `BENCH_replay.json`
//! via `tools/perf_baseline.py record`.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::config::SharedMemConfig;
use sparsezipper::mem::{replay, TraceBuf, TraceEvent, TraceKind};
use sparsezipper::SystemConfig;

/// Deterministic per-core trace: a streaming sweep interleaved with writes
/// into a shared hot window (every core touches the same `hot` lines, so
/// the replay sees upgrades, invalidations, forwards, and demand misses —
/// the full merge-phase workload, not a hit-only fast path).
fn synth_traces(cores: usize, events: usize) -> Vec<TraceBuf> {
    let hot = 4096u64;
    (0..cores)
        .map(|c| {
            let mut buf = TraceBuf::new();
            let mut x = 0x9e3779b97f4a7c15u64.wrapping_mul(c as u64 + 1) | 1;
            for i in 0..events {
                // xorshift64* — cheap, deterministic, and seeded per core.
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let r = x.wrapping_mul(0x2545f4914f6cdd1d);
                let (line, write) = if r % 3 == 0 {
                    (1 << 30 | (r >> 8) % hot, r % 2 == 0) // shared hot window
                } else {
                    ((c as u64) << 24 | i as u64, false) // private stream
                };
                let shadow_hit = r % 5 == 0;
                let e = TraceEvent::new(line, TraceKind::Demand, write, shadow_hit, !shadow_hit, 2);
                buf.push(e, i as f64 * 4.0);
            }
            buf
        })
        .collect()
}

fn main() {
    let events: usize = std::env::var("SPZ_BENCH_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300_000);
    let reps = bench_util::reps();
    let cores = 8;
    let sys = SystemConfig::default();
    let traces = synth_traces(cores, events);
    println!("== replay shards ({cores} cores x {events} events) ==");

    let serial = replay(&sys.mem, &sys.shared, &traces);
    for shards in [1usize, 2, 4, 8] {
        let cfg = SharedMemConfig { replay_shards: shards, ..sys.shared };
        // Correctness gate first: the knob must not move a single bit.
        assert_eq!(replay(&sys.mem, &cfg, &traces), serial, "shards={shards} diverged");
        bench_util::bench(&format!("replay shards={shards}"), reps, || {
            std::hint::black_box(replay(&sys.mem, &cfg, &traces));
        });
    }
}
