//! Bench: streaming trace replay — the materialize-then-replay `TraceBuf`
//! path against the bounded-ring streaming pipeline (producer threads
//! feeding `TraceWriter`s while the `ReplayEngine` consumes concurrently),
//! at 1/4/8 replay shards and with a spill-forced 4-chunk ring. Every
//! configuration is asserted bit-identical to the materialized baseline
//! before it is timed (modulo the two ring-shaped footprint counters, which
//! are zeroed exactly as the stable JSON does), so a speedup can never be
//! bought with a results drift.
//!
//! `SPZ_BENCH_EVENTS` scales the per-core event count (default 300k);
//! `SPZ_BENCH_REPS` the repetitions. Medians land in `BENCH_trace.json`
//! via `tools/perf_baseline.py record`.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::config::{MemConfig, SharedMemConfig};
use sparsezipper::mem::{
    replay, ReplayEngine, ReplayOutcome, TraceBuf, TraceEvent, TraceKind, TraceSource, TraceStream,
};
use sparsezipper::SystemConfig;

/// Deterministic per-core trace: a streaming sweep interleaved with writes
/// into a shared hot window (same generator as the `replay_shards` bench,
/// so the two baselines stay comparable).
fn synth_traces(cores: usize, events: usize) -> Vec<TraceBuf> {
    let hot = 4096u64;
    (0..cores)
        .map(|c| {
            let mut buf = TraceBuf::new();
            let mut x = 0x9e3779b97f4a7c15u64.wrapping_mul(c as u64 + 1) | 1;
            for i in 0..events {
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let r = x.wrapping_mul(0x2545f4914f6cdd1d);
                let (line, write) = if r % 3 == 0 {
                    (1 << 30 | (r >> 8) % hot, r % 2 == 0) // shared hot window
                } else {
                    ((c as u64) << 24 | i as u64, false) // private stream
                };
                let shadow_hit = r % 5 == 0;
                let e = TraceEvent::new(line, TraceKind::Demand, write, shadow_hit, !shadow_hit, 2);
                buf.push(e, i as f64 * 4.0);
            }
            buf
        })
        .collect()
}

/// Replay through the streaming pipeline: one producer thread per core
/// re-emits its materialized trace into a `ring`-chunk `TraceWriter` while
/// the engine consumes the streams concurrently — the same shape the
/// parallel SpGEMM driver runs, minus the simulation itself.
fn replay_streamed(
    mem: &MemConfig,
    cfg: &SharedMemConfig,
    traces: &[TraceBuf],
    ring: usize,
) -> ReplayOutcome {
    let (writers, streams): (Vec<_>, Vec<_>) =
        (0..traces.len()).map(|_| TraceStream::channel(ring)).unzip();
    std::thread::scope(|scope| {
        for (t, mut w) in traces.iter().zip(writers) {
            scope.spawn(move || {
                for (time, e) in t.iter_timed() {
                    w.push(e, time);
                }
                w.finish();
            });
        }
        ReplayEngine::from_source(mem, cfg, TraceSource::Streams(&streams)).run()
    })
}

/// Zero the ring-shaped footprint counters (resident peak and spill count),
/// exactly as `to_json_stable` does: they describe *how* the trace was
/// held, never what the replay computed.
fn strip_ring_counters(mut o: ReplayOutcome) -> ReplayOutcome {
    for s in &mut o.per_core {
        s.trace_peak_resident_chunks = 0;
        s.spilled_chunks = 0;
    }
    o
}

fn main() {
    let events: usize = std::env::var("SPZ_BENCH_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300_000);
    let reps = bench_util::reps();
    let cores = 8;
    let sys = SystemConfig::default();
    let traces = synth_traces(cores, events);
    println!("== trace streaming ({cores} cores x {events} events) ==");

    for shards in [1usize, 4, 8] {
        let cfg = SharedMemConfig { replay_shards: shards, ..sys.shared };
        let materialized = replay(&sys.mem, &cfg, &traces);
        // Correctness gates first: an unbounded ring is fully bit-identical
        // (footprint counters included); a spill-forced 4-chunk ring matches
        // everywhere but the ring-shaped counters it exists to change.
        assert_eq!(
            replay_streamed(&sys.mem, &cfg, &traces, 0),
            materialized,
            "shards={shards}: streamed replay diverged"
        );
        assert_eq!(
            strip_ring_counters(replay_streamed(&sys.mem, &cfg, &traces, 4)),
            strip_ring_counters(materialized.clone()),
            "shards={shards}: spill-forced replay diverged"
        );
        bench_util::bench(&format!("trace materialized shards={shards}"), reps, || {
            std::hint::black_box(replay(&sys.mem, &cfg, &traces));
        });
        bench_util::bench(&format!("trace streamed shards={shards}"), reps, || {
            std::hint::black_box(replay_streamed(&sys.mem, &cfg, &traces, 0));
        });
        bench_util::bench(&format!("trace streamed ring=4 shards={shards}"), reps, || {
            std::hint::black_box(replay_streamed(&sys.mem, &cfg, &traces, 4));
        });
    }
}
