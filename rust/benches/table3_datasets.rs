//! Bench: Table III regeneration — dataset synthesis + characterization
//! cost per matrix, plus the rendered paper-vs-measured table.
//!
//! `SPZ_BENCH_SCALE=1.0 cargo bench --bench table3_datasets` reproduces the
//! full-size table.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::matrix::{registry, stats};

fn main() {
    let scale = bench_util::scale();
    println!("== Table III dataset suite (scale {scale}) ==");
    let mut total_nnz = 0usize;
    for d in registry::DATASETS {
        let mut built = None;
        bench_util::bench(&format!("build {}", d.name), bench_util::reps(), || {
            built = Some(d.build(scale));
        });
        let m = built.unwrap();
        total_nnz += m.nnz();
        bench_util::bench(&format!("characterize {}", d.name), 1, || {
            let st = stats::characterize(&m, 16);
            assert!(st.nnz > 0);
        });
    }
    println!("total nnz across suite: {total_nnz}");
}
