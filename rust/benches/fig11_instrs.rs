//! Bench: Figure 11 regeneration — dynamic mssortk/mszipk instruction
//! counts, spz vs spz-rsort (the work-balance effect of row sorting).

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{Session, SuiteSpec};
use sparsezipper::coordinator::figures;
use sparsezipper::ImplId;

fn main() {
    let session = Session::new();
    let spec = SuiteSpec {
        scale: bench_util::scale(),
        impls: vec![ImplId::Spz, ImplId::SpzRsort],
        ..Default::default()
    };
    println!("== Figure 11 (scale {}) ==", spec.scale);
    let mut out = None;
    bench_util::bench("fig11 suite", 1, || {
        out = Some(session.run_suite(&spec).expect("suite"));
    });
    println!("{}", figures::fig11(&out.unwrap()));
}
