//! Bench: Figure 11 regeneration — dynamic mssortk/mszipk instruction
//! counts, spz vs spz-rsort (the work-balance effect of row sorting).

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::coordinator::{figures, run_suite, SuiteConfig};

fn main() {
    let cfg = SuiteConfig {
        scale: bench_util::scale(),
        impls: vec!["spz".into(), "spz-rsort".into()],
        ..Default::default()
    };
    println!("== Figure 11 (scale {}) ==", cfg.scale);
    let mut out = None;
    bench_util::bench("fig11 suite", 1, || {
        out = Some(run_suite(&cfg).expect("suite"));
    });
    println!("{}", figures::fig11(&out.unwrap()));
}
