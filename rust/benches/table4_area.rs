//! Bench: Table IV regeneration — component-level area model at the paper's
//! design point plus a design-space sweep over array sizes.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::area::AreaModel;

fn main() {
    println!("{}", AreaModel::paper().table4());
    println!("Design-space sweep (baseline vs SparseZipper, k um^2):");
    for n in [4usize, 8, 16, 32, 64] {
        let m = AreaModel { n, num_regs: 16 };
        println!(
            "  N={n:<3} baseline {:>10.2}   spz {:>10.2}   overhead {:>6.2}%",
            m.baseline_total(),
            m.spz_total(),
            m.overhead_pct()
        );
    }
    bench_util::bench("area model eval (paper point)", 3, || {
        let m = AreaModel::paper();
        assert!(m.overhead_pct() > 0.0);
    });
}
