//! Bench: Figure 12 multi-core scaling — spz over the dataset suite at
//! 1/2/4/8 simulated cores, static vs work-stealing block schedules.
//!
//! `SPZ_BENCH_SCALE=1.0 cargo bench --bench fig12_scaling` = full size.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{DatasetSource, Session};
use sparsezipper::coordinator::figures;
use sparsezipper::matrix::registry;
use sparsezipper::spgemm::parallel::Scheduler;
use sparsezipper::ImplId;

fn main() {
    let session = Session::new();
    let datasets: Vec<DatasetSource> =
        registry::DATASETS.iter().map(DatasetSource::Registry).collect();
    let cores = [1usize, 2, 4, 8];
    println!(
        "== Figure 12 ({} datasets, cores {:?}, scale {}) ==",
        datasets.len(),
        cores,
        bench_util::scale()
    );
    let mut out = None;
    bench_util::bench("fig12 scaling sweep (spz)", 1, || {
        out = Some(
            figures::scaling_sweep(&session, &datasets, ImplId::Spz, bench_util::scale(), &cores)
                .expect("scaling sweep"),
        );
    });
    let points = out.unwrap();
    println!("{}", figures::fig12(&points));
    // Imbalance headline: how much work-stealing buys over static at 8 cores.
    let gain: Vec<f64> = points
        .iter()
        .filter(|p| p.cores == 8 && p.scheduler == Some(Scheduler::Static))
        .filter_map(|st| {
            points
                .iter()
                .find(|ws| {
                    ws.dataset == st.dataset
                        && ws.cores == 8
                        && ws.scheduler == Some(Scheduler::WorkStealing)
                })
                .map(|ws| ws.speedup / st.speedup)
        })
        .collect();
    if !gain.is_empty() {
        let g = gain.iter().product::<f64>().powf(1.0 / gain.len() as f64);
        println!("geomean work-stealing/static speedup at 8 cores: {g:.3}x");
    }
}
