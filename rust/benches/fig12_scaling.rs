//! Bench: Figure 12 multi-core scaling — spz over the dataset suite at
//! 1/2/4/8 simulated cores, static vs work-stealing block schedules.
//!
//! `SPZ_BENCH_SCALE=1.0 cargo bench --bench fig12_scaling` = full size.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{DatasetSource, Session};
use sparsezipper::coordinator::figures;
use sparsezipper::matrix::registry;
use sparsezipper::spgemm::parallel::Scheduler;
use sparsezipper::ImplId;

fn main() {
    let session = Session::new();
    let datasets: Vec<DatasetSource> =
        registry::DATASETS.iter().map(DatasetSource::Registry).collect();
    let cores = [1usize, 2, 4, 8];
    println!(
        "== Figure 12 ({} datasets, cores {:?}, scale {}) ==",
        datasets.len(),
        cores,
        bench_util::scale()
    );
    let mut out = None;
    bench_util::bench("fig12 scaling sweep (spz)", 1, || {
        out = Some(
            figures::scaling_sweep(
                &session,
                &datasets,
                ImplId::Spz,
                bench_util::scale(),
                &cores,
                &Scheduler::ALL,
            )
            .expect("scaling sweep"),
        );
    });
    let points = out.unwrap();
    println!("{}", figures::fig12(&points));
    // Imbalance headline: how much work-stealing buys over static at 8 cores.
    let gain: Vec<f64> = points
        .iter()
        .filter(|p| p.cores == 8 && p.scheduler == Some(Scheduler::Static))
        .filter_map(|st| {
            points
                .iter()
                .find(|ws| {
                    ws.dataset == st.dataset
                        && ws.cores == 8
                        && ws.scheduler == Some(Scheduler::WorkStealing)
                })
                .map(|ws| ws.speedup / st.speedup)
        })
        .collect();
    if !gain.is_empty() {
        let g = gain.iter().product::<f64>().powf(1.0 / gain.len() as f64);
        println!("geomean work-stealing/static speedup at 8 cores: {g:.3}x");
    }
    // Shared-memory headline: how much real sharing/contention the replay
    // saw at 8 cores (the analytic constants this model replaced were blind
    // to both).
    let at8: Vec<_> = points
        .iter()
        .filter(|p| p.cores == 8 && p.scheduler == Some(Scheduler::WorkStealing))
        .collect();
    if !at8.is_empty() {
        let hit = at8.iter().map(|p| p.llc_hit_rate).sum::<f64>() / at8.len() as f64;
        let coh: u64 = at8.iter().map(|p| p.coherence_events).sum();
        let dq: f64 = at8.iter().map(|p| p.dram_queue_cycles).sum();
        println!(
            "shared memory at 8 cores (work-stealing): mean LLC hit {:.1}%, \
             {coh} coherence events, {dq:.0} DRAM queue cycles",
            100.0 * hit
        );
    }
}
