//! Bench: Figure 10 regeneration — L1 data-cache access counts of
//! vec-radix vs spz (exact event counts from the cache simulation).

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::coordinator::{figures, run_suite, SuiteConfig};

fn main() {
    let cfg = SuiteConfig {
        scale: bench_util::scale(),
        impls: vec!["vec-radix".into(), "spz".into()],
        ..Default::default()
    };
    println!("== Figure 10 (scale {}) ==", cfg.scale);
    let mut out = None;
    bench_util::bench("fig10 suite", 1, || {
        out = Some(run_suite(&cfg).expect("suite"));
    });
    println!("{}", figures::fig10(&out.unwrap()));
}
