//! Bench: Figure 10 regeneration — L1 data-cache access counts of
//! vec-radix vs spz (exact event counts from the cache simulation).

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{Session, SuiteSpec};
use sparsezipper::coordinator::figures;
use sparsezipper::ImplId;

fn main() {
    let session = Session::new();
    let spec = SuiteSpec {
        scale: bench_util::scale(),
        impls: vec![ImplId::VecRadix, ImplId::Spz],
        ..Default::default()
    };
    println!("== Figure 10 (scale {}) ==", spec.scale);
    let mut out = None;
    bench_util::bench("fig10 suite", 1, || {
        out = Some(session.run_suite(&spec).expect("suite"));
    });
    println!("{}", figures::fig10(&out.unwrap()));
}
