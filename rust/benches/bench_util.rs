//! Minimal bench harness shared by the `cargo bench` targets (criterion is
//! not in the offline vendor set). Reports median / mean / min over R
//! repetitions, honouring `SPZ_BENCH_SCALE` (dataset scale) and
//! `SPZ_BENCH_REPS`.

use std::time::Instant;

#[allow(dead_code)]
pub fn scale() -> f64 {
    std::env::var("SPZ_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

#[allow(dead_code)]
pub fn reps() -> usize {
    std::env::var("SPZ_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
}

/// Time `f` `reps` times; print a bench line; return the per-rep seconds.
#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> Vec<f64> {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "bench {name:<40} median {:>10.3} ms   mean {:>10.3} ms   min {:>10.3} ms   ({} reps)",
        median * 1e3,
        mean * 1e3,
        sorted[0] * 1e3,
        reps
    );
    times
}

/// ns/op microbenchmark for hot-path functions.
#[allow(dead_code)]
pub fn bench_ns<F: FnMut() -> u64>(name: &str, mut f: F) {
    // Warm up, then measure; f returns the op count it performed.
    let _ = f();
    let t0 = Instant::now();
    let mut ops = 0u64;
    let mut iters = 0;
    while t0.elapsed().as_secs_f64() < 0.5 || iters < 3 {
        ops += f();
        iters += 1;
    }
    let ns = t0.elapsed().as_nanos() as f64 / ops.max(1) as f64;
    println!("bench {name:<40} {ns:>10.1} ns/op   ({ops} ops)");
}
