//! Bench: `ws-adapt`'s *decision* cost in isolation — barrier-aware plan
//! construction ([`phase_aware_claims`] + [`phase_makespan`] scoring) over
//! synthetic per-block phase costs, and the pilot's replay over a
//! deterministic coherence-heavy trace set — never the SpGEMM kernels
//! themselves. This is the overhead a job pays for adaptive scheduling, so
//! it is tracked separately from the kernel figures.
//!
//! `SPZ_BENCH_EVENTS` scales the per-core pilot-trace event count (default
//! 100k); `SPZ_BENCH_REPS` the repetitions. Medians land in
//! `BENCH_adapt.json` via `tools/perf_baseline.py record`.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::config::SharedMemConfig;
use sparsezipper::mem::{replay, TraceBuf, TraceEvent, TraceKind};
use sparsezipper::sim::machine::NUM_PHASES;
use sparsezipper::spgemm::parallel::{phase_aware_claims, phase_makespan};
use sparsezipper::SystemConfig;

/// Deterministic per-block, per-phase costs with a skewed distribution
/// (xorshift64*), shaped like the probe output on a hub-heavy matrix.
fn synth_costs(nblocks: usize) -> Vec<[f64; NUM_PHASES]> {
    let mut x = 0x243f6a8885a308d3u64 | 1;
    (0..nblocks)
        .map(|bi| {
            let mut p = [0.0f64; NUM_PHASES];
            for v in p.iter_mut() {
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let r = x.wrapping_mul(0x2545f4914f6cdd1d);
                // Every 8th block is a "hub": ~16x the base cost.
                let hub = if bi % 8 == 0 { 16.0 } else { 1.0 };
                *v = hub * ((r >> 40) as f64 + 1.0);
            }
            p
        })
        .collect()
}

/// Deterministic coherence-heavy traces (same shape as the replay bench):
/// a private stream interleaved with writes into a shared hot window.
fn synth_traces(cores: usize, events: usize) -> Vec<TraceBuf> {
    let hot = 4096u64;
    (0..cores)
        .map(|c| {
            let mut buf = TraceBuf::new();
            let mut x = 0x9e3779b97f4a7c15u64.wrapping_mul(c as u64 + 1) | 1;
            for i in 0..events {
                x ^= x >> 12;
                x ^= x << 25;
                x ^= x >> 27;
                let r = x.wrapping_mul(0x2545f4914f6cdd1d);
                let (line, write) = if r % 3 == 0 {
                    (1 << 30 | (r >> 8) % hot, r % 2 == 0)
                } else {
                    ((c as u64) << 24 | i as u64, false)
                };
                let shadow_hit = r % 5 == 0;
                let e = TraceEvent::new(line, TraceKind::Demand, write, shadow_hit, !shadow_hit, 2);
                buf.push(e, i as f64 * 4.0);
            }
            buf
        })
        .collect()
}

fn main() {
    let events: usize = std::env::var("SPZ_BENCH_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let reps = bench_util::reps();
    let cores = 8;
    println!("== adapt scheduler decisions ({cores} cores) ==");

    // Plan construction: the barrier-aware claim plus one makespan scoring
    // pass per fixed-candidate slot (ws-adapt scores four).
    for &nblocks in &[64usize, 512] {
        let costs = synth_costs(nblocks);
        let stalls: Vec<f64> = (0..cores).map(|c| (c * 37) as f64).collect();
        bench_util::bench_ns(&format!("adapt plan blocks={nblocks}"), || {
            let plan = phase_aware_claims(&costs, cores);
            for _ in 0..4 {
                std::hint::black_box(phase_makespan(&costs, &plan, &stalls));
            }
            1
        });
    }

    // Pilot replay: the other half of the decision cost. Sharding is gated
    // on bit-identity before it is timed, as in the replay bench.
    let sys = SystemConfig::default();
    let traces = synth_traces(cores, events);
    let serial = replay(&sys.mem, &sys.shared, &traces);
    let sharded_cfg = SharedMemConfig { replay_shards: 4, ..sys.shared };
    assert_eq!(replay(&sys.mem, &sharded_cfg, &traces), serial, "4-shard pilot diverged");
    bench_util::bench("pilot serial", reps, || {
        std::hint::black_box(replay(&sys.mem, &sys.shared, &traces));
    });
    bench_util::bench("pilot sharded=4", reps, || {
        std::hint::black_box(replay(&sys.mem, &sharded_cfg, &traces));
    });
}
