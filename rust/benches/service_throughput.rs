//! Bench: service-layer overhead — jobs/second through the multi-tenant
//! `SimService` pool versus the same jobs as direct `Session::run` calls in
//! a loop. The workload (many small SpGEMM jobs on cached datasets) makes
//! queueing, DRR scheduling, and handle completion the measured quantity.
//!
//! `SPZ_BENCH_REPS=5 cargo bench --bench service_throughput` for more reps.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{DatasetSource, JobSpec, Session};
use sparsezipper::matrix::gen;
use sparsezipper::service::{Backpressure, SimService, SimServiceConfig};
use sparsezipper::ImplId;
use std::sync::Arc;

const TENANTS: usize = 4;
const JOBS_PER_TENANT: usize = 64;

fn sources() -> Vec<DatasetSource> {
    (0..TENANTS)
        .map(|i| {
            DatasetSource::in_memory(
                format!("svc-bench{i}"),
                Arc::new(gen::erdos_renyi(64, 64, 320, 900 + i as u64)),
            )
        })
        .collect()
}

fn main() {
    let reps = bench_util::reps();
    let total = TENANTS * JOBS_PER_TENANT;
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8);
    println!(
        "== service throughput ({TENANTS} tenants x {JOBS_PER_TENANT} jobs, {workers} workers) =="
    );

    // Baseline: the same jobs, serial direct calls, no service layer.
    {
        let session = Session::new();
        let sources = sources();
        // Pre-build the datasets/oracles so both sides measure steady state.
        for src in &sources {
            session.run(&JobSpec::new(ImplId::SclHash, src.clone())).expect("warmup");
        }
        let times = bench_util::bench(&format!("direct Session::run x{total}"), reps, || {
            for src in &sources {
                for _ in 0..JOBS_PER_TENANT {
                    session.run(&JobSpec::new(ImplId::SclHash, src.clone())).expect("job");
                }
            }
        });
        report_rate("direct", total, &times);
    }

    // Through the service: concurrent tenants, bounded queue, DRR, handles.
    {
        let session = Session::new();
        let sources = sources();
        for src in &sources {
            session.run(&JobSpec::new(ImplId::SclHash, src.clone())).expect("warmup");
        }
        let times = bench_util::bench(&format!("SimService submit/wait x{total}"), reps, || {
            let svc = SimService::start(
                session.clone(),
                SimServiceConfig {
                    workers,
                    queue_depth: 64,
                    backpressure: Backpressure::Block,
                    ..SimServiceConfig::default()
                },
            )
            .expect("service");
            std::thread::scope(|scope| {
                for (i, src) in sources.iter().enumerate() {
                    let svc = &svc;
                    scope.spawn(move || {
                        let tenant = format!("t{i}");
                        let handles: Vec<_> = (0..JOBS_PER_TENANT)
                            .map(|_| {
                                svc.submit(&tenant, JobSpec::new(ImplId::SclHash, src.clone()))
                                    .expect("submit")
                            })
                            .collect();
                        for h in handles {
                            h.wait().expect("job");
                        }
                    });
                }
            });
            let stats = svc.stats();
            assert_eq!(stats.completed, total as u64);
        });
        report_rate("service", total, &times);
    }
}

fn report_rate(what: &str, total: usize, times: &[f64]) {
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    println!("{what}: {:.0} jobs/s (median rep)", total as f64 / median.max(1e-9));
}
