//! Hot-path microbenchmarks — the §Perf targets in EXPERIMENTS.md.
//!
//! * `zip_step` / `sort_step` (native engine): called O(total_work / N)
//!   times per SpGEMM — the simulator's inner loop.
//! * cache `access_line`: every simulated memory event probes it.
//! * PE-level array sim (validation-path cost).
//! * expansion-phase machine accounting.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::config::SystemConfig;
use sparsezipper::mem::{AccessKind, Hierarchy};
use sparsezipper::runtime::{NativeEngine, ZipUnit};
use sparsezipper::systolic::array;
use sparsezipper::util::Pcg32;

fn mk_group(rng: &mut Pcg32, s: usize, n: usize) -> (Vec<Vec<u32>>, Vec<Vec<f32>>) {
    let mut ks = Vec::with_capacity(s);
    let mut vs = Vec::with_capacity(s);
    for _ in 0..s {
        let len = 1 + rng.gen_usize(n);
        let mut k: Vec<u32> = (0..len).map(|_| rng.gen_range(1000)).collect();
        k.sort_unstable();
        k.dedup();
        let v = vec![1.0f32; k.len()];
        ks.push(k);
        vs.push(v);
    }
    (ks, vs)
}

fn main() {
    let mut rng = Pcg32::new(1);

    // Native zip_step over a full 16-stream group.
    {
        let mut eng = NativeEngine::new(16);
        let (k0, v0) = mk_group(&mut rng, 16, 16);
        let (k1, v1) = mk_group(&mut rng, 16, 16);
        bench_util::bench_ns("native zip_step (16 streams)", || {
            let out = eng.zip_step(&k0, &v0, &k1, &v1).unwrap();
            std::hint::black_box(&out);
            16
        });
    }

    // Native sort_step.
    {
        let mut eng = NativeEngine::new(16);
        let (k0, v0) = mk_group(&mut rng, 16, 16);
        let (k1, v1) = mk_group(&mut rng, 16, 16);
        bench_util::bench_ns("native sort_step (16 streams)", || {
            let out = eng.sort_step(&k0, &v0, &k1, &v1).unwrap();
            std::hint::black_box(&out);
            16
        });
    }

    // Cache hierarchy probe: mixed hit/miss stream.
    {
        let mut h = Hierarchy::new(SystemConfig::default().mem);
        let addrs: Vec<u64> = (0..4096u64).map(|i| 0x100000 + (i * 2377) % 65536 * 64).collect();
        bench_util::bench_ns("hierarchy access_line (mixed)", || {
            for &a in &addrs {
                std::hint::black_box(h.access_line(a >> 6, AccessKind::Read));
            }
            addrs.len() as u64
        });
    }

    // PE-level array zip (validation path).
    {
        let a: Vec<(u32, f32)> = (0..16).map(|i| (i * 3, 1.0)).collect();
        let b: Vec<(u32, f32)> = (0..16).map(|i| (i * 2 + 1, 1.0)).collect();
        bench_util::bench_ns("PE-array run_zip 16x16", || {
            std::hint::black_box(array::run_zip(16, &a, &b));
            1
        });
    }

    // End-to-end small spz run (machine accounting + engine).
    {
        use sparsezipper::sim::Machine;
        use sparsezipper::spgemm::{spz::Spz, SpGemm};
        let a = sparsezipper::matrix::gen::powerlaw_clustered(2000, 12000, 1.0, 0.4, 5);
        bench_util::bench_ns("spz end-to-end (2k rows, 12k nnz)", || {
            let mut m = Machine::new(SystemConfig::default());
            let c = Spz::native().multiply(&mut m, &a, &a).unwrap();
            std::hint::black_box(c.nnz()) as u64
        });
    }
}
