//! Bench: Figure 8 end-to-end regeneration — all five implementations over
//! the dataset suite; prints the speedup table and the headline geomeans
//! next to the paper's numbers.
//!
//! `SPZ_BENCH_SCALE=1.0 cargo bench --bench fig8_speedup` = full size.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::api::{Session, SuiteSpec};
use sparsezipper::coordinator::figures;

fn main() {
    let session = Session::new();
    let spec = SuiteSpec {
        scale: bench_util::scale(),
        ..Default::default()
    };
    println!(
        "== Figure 8 ({} datasets x {} impls, scale {}) ==",
        spec.datasets.len(),
        spec.impls.len(),
        spec.scale
    );
    let mut out = None;
    bench_util::bench("fig8 full suite", 1, || {
        out = Some(session.run_suite(&spec).expect("suite"));
    });
    let suite = out.unwrap();
    println!("{}", figures::fig8(&suite));
    for r in &suite.results {
        println!(
            "  sim {:<10} {:<10} {:>9.3}s wall  {:>14.0} cycles",
            r.impl_id, r.dataset, r.wall_secs, r.metrics.cycles
        );
    }
}
