//! Bench: Figure 8 end-to-end regeneration — all five implementations over
//! the dataset suite; prints the speedup table and the headline geomeans
//! next to the paper's numbers.
//!
//! `SPZ_BENCH_SCALE=1.0 cargo bench --bench fig8_speedup` = full size.

#[path = "bench_util.rs"]
mod bench_util;

use sparsezipper::coordinator::{figures, run_suite, SuiteConfig};

fn main() {
    let cfg = SuiteConfig {
        scale: bench_util::scale(),
        ..Default::default()
    };
    println!(
        "== Figure 8 ({} datasets x {} impls, scale {}) ==",
        cfg.datasets.len(),
        cfg.impls.len(),
        cfg.scale
    );
    let mut out = None;
    bench_util::bench("fig8 full suite", 1, || {
        out = Some(run_suite(&cfg).expect("suite"));
    });
    let suite = out.unwrap();
    println!("{}", figures::fig8(&suite));
    for r in &suite.results {
        println!(
            "  sim {:<10} {:<10} {:>9.3}s wall  {:>14.0} cycles",
            r.impl_name, r.dataset, r.wall_secs, r.metrics.cycles
        );
    }
}
