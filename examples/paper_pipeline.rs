//! End-to-end paper pipeline — the repository's E2E validation driver
//! (EXPERIMENTS.md records its output).
//!
//! Runs the full evaluation through one [`Session`]: builds the Table III
//! dataset suite (each matrix and its reference product exactly once, via
//! the session cache), executes all five SpGEMM implementations through the
//! cycle-level simulator with functional verification on every product,
//! regenerates Figure 8 (the headline speedups), the Figure 9 breakdown,
//! Figure 10 (L1D accesses) and Figure 11 (dynamic instruction counts),
//! runs the Table IV area model, exports the structured `suite.json`, and
//! checks the paper's qualitative claims.
//!
//! ```bash
//! cargo run --release --example paper_pipeline -- [scale] [out_dir]
//! # scale in (0,1]; default 0.25 keeps the run to a few minutes.
//! ```

use sparsezipper::api::{Session, SuiteSpec};
use sparsezipper::area::AreaModel;
use sparsezipper::coordinator::{figures, report};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let scale: f64 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(0.25);
    let out_dir = std::path::PathBuf::from(
        args.next().unwrap_or_else(|| "reports/pipeline".to_string()),
    );

    let session = Session::new();
    let spec = SuiteSpec {
        scale,
        verify: true, // every product checked against the oracle
        ..Default::default()
    };
    println!(
        "[paper_pipeline] {} datasets x {} impls at scale {} (verified)",
        spec.datasets.len(),
        spec.impls.len(),
        scale
    );
    let t0 = std::time::Instant::now();
    let suite = session.run_suite(&spec)?;
    println!(
        "[paper_pipeline] suite complete in {:.1}s — all {} products verified ({} dataset builds, {} oracles)",
        t0.elapsed().as_secs_f64(),
        suite.results.len(),
        session.dataset_builds(),
        session.reference_builds()
    );

    report::emit(&out_dir, "table3.txt", &figures::table3(&suite), false)?;
    report::emit(&out_dir, "fig8.txt", &figures::fig8(&suite), false)?;
    report::emit(&out_dir, "fig9.txt", &figures::fig9(&suite), true)?;
    report::emit(&out_dir, "fig10.txt", &figures::fig10(&suite), false)?;
    report::emit(&out_dir, "fig11.txt", &figures::fig11(&suite), false)?;
    report::emit(&out_dir, "table4.txt", &AreaModel::paper().table4(), false)?;
    report::emit(&out_dir, "suite.json", &suite.to_json(), true)?;
    for (name, content) in figures::tsv_exports(&suite) {
        report::emit(&out_dir, &name, &content, true)?;
    }

    // Qualitative shape checks (who wins, where, why).
    let checks = figures::shape_checks(&suite);
    println!("\nShape checks (paper's qualitative claims):");
    let mut failures = 0;
    for (name, ok) in &checks {
        println!("  [{}] {}", if *ok { "ok" } else { "FAIL" }, name);
        if !*ok {
            failures += 1;
        }
    }
    println!(
        "\n{}/{} checks passed; reports in {}",
        checks.len() - failures,
        checks.len(),
        out_dir.display()
    );
    anyhow::ensure!(failures == 0, "{failures} shape checks failed");
    Ok(())
}
