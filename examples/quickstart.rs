//! Quickstart: multiply a small sparse matrix by itself with the
//! SparseZipper implementation, verify against the reference oracle, and
//! print the simulated speedup over the scalar hash baseline.
//!
//! ```bash
//! cargo run --release --example quickstart            # native engine
//! SPZ_ENGINE=xla cargo run --release --example quickstart   # AOT/PJRT engine
//! ```

use sparsezipper::config::SystemConfig;
use sparsezipper::matrix::gen;
use sparsezipper::runtime::client::{artifact_dir, artifacts_available};
use sparsezipper::sim::Machine;
use sparsezipper::spgemm::{self, SpGemm};

fn main() -> anyhow::Result<()> {
    // A small scale-free graph, the paper's motivating workload shape.
    let a = gen::powerlaw_clustered(2000, 12_000, 1.0, 0.4, 42);
    println!(
        "A: {} x {} with {} nonzeros (density {:.2e})",
        a.nrows,
        a.ncols,
        a.nnz(),
        a.density()
    );

    // Engine selection: native Rust semantics, or the AOT-compiled
    // JAX/Pallas datapath through the PJRT CPU client.
    let use_xla = std::env::var("SPZ_ENGINE").map(|e| e == "xla").unwrap_or(false);
    let mut spz: Box<dyn SpGemm> = if use_xla {
        let dir = artifact_dir();
        anyhow::ensure!(
            artifacts_available(&dir),
            "artifacts missing — run `make artifacts` first"
        );
        println!("engine: xla (artifacts from {})", dir.display());
        Box::new(spgemm::spz::Spz::xla(&dir)?)
    } else {
        println!("engine: native");
        Box::new(spgemm::spz::Spz::native())
    };

    // Run SparseZipper SpGEMM under the cycle model.
    let mut m_spz = Machine::new(SystemConfig::default());
    let c = spz.multiply(&mut m_spz, &a, &a)?;

    // Verify against the independent oracle.
    let reference = spgemm::reference(&a, &a);
    anyhow::ensure!(
        spgemm::same_product(&c, &reference, 1e-3),
        "product mismatch!"
    );
    println!(
        "C = A*A: {} nonzeros — verified against reference oracle",
        c.nnz()
    );

    // Compare with the scalar hash baseline.
    let mut m_hash = Machine::new(SystemConfig::default());
    spgemm::scl_hash::SclHash.multiply(&mut m_hash, &a, &a)?;

    let spz_m = m_spz.metrics();
    let hash_m = m_hash.metrics();
    println!("\nsimulated cycles:");
    println!("  scl-hash : {:>14.0}", hash_m.cycles);
    println!("  spz      : {:>14.0}", spz_m.cycles);
    println!("  speedup  : {:>13.2}x", hash_m.cycles / spz_m.cycles);
    println!(
        "\nspz dynamic matrix instructions: {} mssortk + {} mszipk ({} mlxe, {} msxe)",
        spz_m.ops.mssortk, spz_m.ops.mszipk, spz_m.ops.mlxe, spz_m.ops.msxe
    );
    println!(
        "L1D accesses: scl-hash {} vs spz {}",
        hash_m.mem.l1d_accesses, spz_m.mem.l1d_accesses
    );
    Ok(())
}
