//! Quickstart for the embeddable Session API: one [`Session`], one
//! in-memory dataset built once, two [`JobSpec`]s (SparseZipper and the
//! scalar hash baseline) verified against a single cached reference oracle,
//! and the simulated speedup between them.
//!
//! ```bash
//! cargo run --release --example quickstart                  # native engine
//! SPZ_ENGINE=xla cargo run --release --example quickstart   # AOT/PJRT engine (--features xla)
//! ```

use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig};
use sparsezipper::matrix::gen;
use sparsezipper::runtime::client::{artifact_dir, artifacts_available};
use sparsezipper::runtime::Engine;
use sparsezipper::ImplId;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // A small scale-free graph, the paper's motivating workload shape.
    let a = gen::powerlaw_clustered(2000, 12_000, 1.0, 0.4, 42);
    println!(
        "A: {} x {} with {} nonzeros (density {:.2e})",
        a.nrows,
        a.ncols,
        a.nnz(),
        a.density()
    );

    // Engine selection: native Rust semantics, or the AOT-compiled
    // JAX/Pallas datapath through the PJRT CPU client.
    let mut cfg = SessionConfig::default();
    if std::env::var("SPZ_ENGINE").map(|e| e == "xla").unwrap_or(false) {
        anyhow::ensure!(
            artifacts_available(&artifact_dir()),
            "artifacts missing — run `make artifacts` first"
        );
        cfg.engine = Engine::Xla;
    }
    println!("engine: {:?}", cfg.engine);
    let session = Session::with_config(cfg);

    // Two verified jobs on the same dataset: the session builds the matrix
    // and the reference oracle exactly once and shares them.
    let dataset = DatasetSource::in_memory("powerlaw-2k", Arc::new(a));
    let spz = session.run(&JobSpec::new(ImplId::Spz, dataset.clone()).with_verify(true))?;
    let hash = session.run(&JobSpec::new(ImplId::SclHash, dataset).with_verify(true))?;
    println!(
        "C = A*A: {} nonzeros — both products verified against the reference oracle",
        spz.out_nnz
    );
    println!(
        "(session cache: dataset built {}x, reference computed {}x across 2 jobs)",
        session.dataset_builds(),
        session.reference_builds()
    );

    println!("\nsimulated cycles:");
    println!("  scl-hash : {:>14.0}", hash.metrics.cycles);
    println!("  spz      : {:>14.0}", spz.metrics.cycles);
    println!("  speedup  : {:>13.2}x", hash.metrics.cycles / spz.metrics.cycles);
    println!(
        "\nspz dynamic matrix instructions: {} mssortk + {} mszipk ({} mlxe, {} msxe)",
        spz.metrics.ops.mssortk, spz.metrics.ops.mszipk, spz.metrics.ops.mlxe, spz.metrics.ops.msxe
    );
    println!(
        "L1D accesses: scl-hash {} vs spz {}",
        hash.metrics.mem.l1d_accesses, spz.metrics.mem.l1d_accesses
    );
    println!("\nstructured result:\n{}", spz.to_json());
    Ok(())
}
