//! Algebraic-multigrid Galerkin triple product R*A*P — the "hybrid linear
//! solvers / algebraic multi-grid" workload from the paper's §I motivation.
//!
//! A is a 2-D Poisson operator; P is a piecewise-constant prolongation from
//! a coarse grid (R = P^T). The coarse operator A_c = R*(A*P) needs two
//! SpGEMMs; both run through the simulated SparseZipper pipeline and are
//! verified against the reference oracle. The example also checks the AMG
//! invariant that the coarse operator preserves the constant vector's
//! nullspace-ish behaviour (row sums of A_c equal the aggregated row sums
//! of A).
//!
//! ```bash
//! cargo run --release --example amg_galerkin [nx]
//! ```

use sparsezipper::api::Session;
use sparsezipper::matrix::{gen, Csr};
use sparsezipper::spgemm;
use sparsezipper::ImplId;

/// Piecewise-constant aggregation prolongation: fine point (x, y) maps to
/// coarse aggregate (x/2, y/2).
fn prolongation(nx: usize, ny: usize) -> Csr {
    let cnx = nx.div_ceil(2);
    let cny = ny.div_ceil(2);
    let mut rows = Vec::with_capacity(nx * ny);
    for y in 0..ny {
        for x in 0..nx {
            let agg = (y / 2) * cnx + x / 2;
            rows.push((vec![agg as u32], vec![1.0f32]));
        }
    }
    Csr::from_rows(nx * ny, cnx * cny, rows)
}

fn main() -> anyhow::Result<()> {
    let nx: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    let ny = nx;

    let a = gen::grid2d(nx, ny, 3);
    let p = prolongation(nx, ny);
    let r = p.transpose();
    println!(
        "A: {0}x{0} 5-point operator ({1} nnz); P: {2} -> {3} aggregates",
        nx * ny,
        a.nnz(),
        p.nrows,
        p.ncols
    );

    let session = Session::new();

    // A_c = R * (A * P): two row-wise SpGEMMs through the session's
    // general-product entry point.
    let ap_run = session.spgemm(ImplId::Spz, &a, &p)?;
    let ap = ap_run.csr;
    let ac_run = session.spgemm(ImplId::Spz, &r, &ap)?;
    let ac = ac_run.csr;
    println!(
        "A*P: {} nnz;  A_c = R*A*P: {} x {} with {} nnz",
        ap.nnz(),
        ac.nrows,
        ac.ncols,
        ac.nnz()
    );

    // Verify both products against the oracle.
    anyhow::ensure!(
        spgemm::same_product(&ap, &spgemm::reference(&a, &p), 1e-3),
        "A*P mismatch"
    );
    anyhow::ensure!(
        spgemm::same_product(&ac, &spgemm::reference(&r, &ap), 1e-3),
        "R*(A*P) mismatch"
    );

    // Galerkin row-sum invariant: sum_j A_c[i][j] = sum over the aggregate's
    // fine rows of A's row sums (P is piecewise-constant).
    let fine_row_sum: Vec<f64> = (0..a.nrows)
        .map(|i| a.row(i).1.iter().map(|&v| v as f64).sum())
        .collect();
    let mut agg_sum = vec![0f64; ac.nrows];
    for (fine, (pk, _)) in (0..p.nrows).map(|i| (i, p.row(i))) {
        agg_sum[pk[0] as usize] += fine_row_sum[fine];
    }
    for i in 0..ac.nrows {
        let s: f64 = ac.row(i).1.iter().map(|&v| v as f64).sum();
        anyhow::ensure!(
            (s - agg_sum[i]).abs() <= 1e-2 * agg_sum[i].abs().max(1.0),
            "row-sum invariant broken at coarse row {i}: {s} vs {}",
            agg_sum[i]
        );
    }
    println!("Galerkin row-sum invariant holds on all {} coarse rows", ac.nrows);

    // Each spgemm() call simulates on a fresh machine (cold caches), so
    // this is the sum of two independent products, not one warm pipeline.
    let (m1, m2) = (&ap_run.metrics, &ac_run.metrics);
    println!(
        "simulated: {:.2}M cycles total (two independent products), {} zip pairs, {} sort pairs",
        (m1.cycles + m2.cycles) / 1e6,
        m1.ops.mszipk + m2.ops.mszipk,
        m1.ops.mssortk + m2.ops.mssortk
    );
    Ok(())
}
