//! Graph analytics on the SpGEMM kernel: triangle counting via
//! tr(A^3)/6 computed with masked row-wise products — one of the paper's
//! §I motivating workloads ("multi-source BFS, peer-pressure clustering,
//! cycle detection, triangle counting").
//!
//! The count is derived from B = A*A (SparseZipper SpGEMM under the cycle
//! model) followed by a masked dot with A: triangles = sum_{(i,j) in A}
//! B[i][j] / 6 for an undirected graph.
//!
//! ```bash
//! cargo run --release --example triangle_counting [n] [avg_degree]
//! ```

use sparsezipper::api::Session;
use sparsezipper::matrix::{gen, Csr};
use sparsezipper::ImplId;

/// Make an undirected (symmetric, zero-diagonal) graph.
fn symmetric_graph(n: usize, nnz: usize, seed: u64) -> Csr {
    let g = gen::powerlaw_clustered(n, nnz / 2, 0.9, 0.5, seed);
    // Symmetrize: A | A^T, drop the diagonal, unit weights.
    let t = g.transpose();
    let mut rows: Vec<(Vec<u32>, Vec<f32>)> = Vec::with_capacity(n);
    for r in 0..n {
        let mut cols: Vec<u32> = g.row(r).0.iter().chain(t.row(r).0).copied().collect();
        cols.sort_unstable();
        cols.dedup();
        cols.retain(|&c| c != r as u32);
        let vals = vec![1.0f32; cols.len()];
        rows.push((cols, vals));
    }
    Csr::from_rows(n, n, rows)
}

/// Exact triangle count by reference (neighbour intersection).
fn reference_triangles(a: &Csr) -> u64 {
    let mut count = 0u64;
    for u in 0..a.nrows {
        let (nu, _) = a.row(u);
        for &v in nu.iter().filter(|&&v| (v as usize) > u) {
            let (nv, _) = a.row(v as usize);
            // |N(u) ∩ N(v)| restricted to w > v to count each triangle once.
            let (mut i, mut j) = (0, 0);
            while i < nu.len() && j < nv.len() {
                match nu[i].cmp(&nv[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if nu[i] > v {
                            count += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    count
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(3000);
    let deg: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(8);

    let a = symmetric_graph(n, n * deg, 7);
    println!(
        "graph: {} vertices, {} edges (avg degree {:.1})",
        a.nrows,
        a.nnz() / 2,
        a.nnz() as f64 / a.nrows as f64
    );

    // B = A*A through the simulated SparseZipper pipeline — the session's
    // general-product entry point for caller-owned matrices.
    let session = Session::new();
    let product = session.spgemm(ImplId::Spz, &a, &a)?;
    let b = product.csr;

    // Masked reduction: sum B[i][j] over edges (i,j) of A. (The mask keeps
    // only wedges that close into triangles; each triangle is counted 6x.)
    let mut closed = 0f64;
    for r in 0..a.nrows {
        let (ak, _) = a.row(r);
        let (bk, bv) = b.row(r);
        let mut i = 0usize;
        for (&col, &val) in bk.iter().zip(bv) {
            while i < ak.len() && ak[i] < col {
                i += 1;
            }
            if i < ak.len() && ak[i] == col {
                closed += val as f64;
            }
        }
    }
    let triangles = (closed / 6.0).round() as u64;
    let expect = reference_triangles(&a);
    println!("triangles: {triangles} (reference: {expect})");
    anyhow::ensure!(triangles == expect, "triangle count mismatch");

    let met = &product.metrics;
    println!(
        "simulated: {:.2}M cycles, {} mssortk + {} mszipk pairs, {:.1}% L1D hit",
        met.cycles / 1e6,
        met.ops.mssortk,
        met.ops.mszipk,
        100.0 * met.mem.l1d_hit_rate()
    );
    println!("verified: masked SpGEMM triangle count matches the exact reference");
    Ok(())
}
