"""Pure-numpy oracle for the SparseZipper sort/zip step semantics.

This is the normative reference the L1 Pallas kernels are tested against
(pytest + hypothesis). It mirrors rust/src/systolic/functional.rs exactly —
the two are kept in lock-step by the golden tests (paper Figure 5 examples)
on both sides.

Conventions:
  * keys: int32, padded with KEY_PAD beyond each stream's length;
  * values: float32, zero-padded;
  * chunk size N = matrix-register row length (16 for the shipped artifacts).
"""

from __future__ import annotations

import numpy as np

KEY_PAD = np.int32(2**31 - 1)


def sort_chunk(keys: np.ndarray, vals: np.ndarray, length: int):
    """Sort one chunk ascending, combining duplicate keys (values summed)."""
    k = np.asarray(keys[:length], dtype=np.int64)
    v = np.asarray(vals[:length], dtype=np.float64)
    order = np.argsort(k, kind="stable")
    k, v = k[order], v[order]
    out_k: list[int] = []
    out_v: list[float] = []
    for i in range(len(k)):
        if out_k and out_k[-1] == k[i]:
            out_v[-1] += v[i]
        else:
            out_k.append(int(k[i]))
            out_v.append(float(v[i]))
    return out_k, out_v


def sort_step_ref(k0, v0, k1, v1, l0, l1, n: int):
    """mssortk+mssortv over a group of streams.

    Returns (k0', v0', k1', v1', ic0, ic1, oc0, oc1) with the same padded
    [S, N] layout as the kernel.
    """
    s = k0.shape[0]
    out = _empty_out(s, n)
    for i in range(s):
        a_k, a_v = sort_chunk(k0[i], v0[i], int(l0[i]))
        b_k, b_v = sort_chunk(k1[i], v1[i], int(l1[i]))
        _write_row(out, 0, i, a_k, a_v)
        _write_row(out, 1, i, b_k, b_v)
        out[4][i] = int(l0[i])
        out[5][i] = int(l1[i])
        out[6][i] = len(a_k)
        out[7][i] = len(b_k)
    return out


def zip_step_ref(k0, v0, k1, v1, l0, l1, n: int):
    """mszipk+mszipv over a group of streams.

    Element x of A is mergeable iff x <= max(B) (merge-bit rule, §IV-B);
    nothing merges against an empty chunk. Mergeable elements merge
    ascending with cross-chunk duplicates combined; the merged sequence
    splits into east = m[:n] (-> k0'/v0') and south = m[n:] (-> k1'/v1').
    ic = consumed per input chunk, oc = output part lengths.
    """
    s = k0.shape[0]
    out = _empty_out(s, n)
    for i in range(s):
        la, lb = int(l0[i]), int(l1[i])
        a = [int(x) for x in k0[i][:la]]
        av = [float(x) for x in v0[i][:la]]
        b = [int(x) for x in k1[i][:lb]]
        bv = [float(x) for x in v1[i][:lb]]
        assert a == sorted(a) and b == sorted(b), "zip inputs must be sorted"
        max_a = a[-1] if a else None
        max_b = b[-1] if b else None
        ca = 0 if max_b is None else sum(1 for x in a if x <= max_b)
        cb = 0 if max_a is None else sum(1 for x in b if x <= max_a)
        # two-pointer merge with duplicate combining
        mk: list[int] = []
        mv: list[float] = []

        def push(k: int, v: float):
            if mk and mk[-1] == k:
                mv[-1] += v
            else:
                mk.append(k)
                mv.append(v)

        ia = ib = 0
        while ia < ca and ib < cb:
            if a[ia] <= b[ib]:
                push(a[ia], av[ia])
                ia += 1
            else:
                push(b[ib], bv[ib])
                ib += 1
        while ia < ca:
            push(a[ia], av[ia])
            ia += 1
        while ib < cb:
            push(b[ib], bv[ib])
            ib += 1

        east_k, east_v = mk[:n], mv[:n]
        south_k, south_v = mk[n:], mv[n:]
        _write_row(out, 0, i, east_k, east_v)
        _write_row(out, 1, i, south_k, south_v)
        out[4][i] = ca
        out[5][i] = cb
        out[6][i] = len(east_k)
        out[7][i] = len(south_k)
    return out


def _empty_out(s: int, n: int):
    return (
        np.full((s, n), KEY_PAD, dtype=np.int32),
        np.zeros((s, n), dtype=np.float32),
        np.full((s, n), KEY_PAD, dtype=np.int32),
        np.zeros((s, n), dtype=np.float32),
        np.zeros((s,), dtype=np.int32),
        np.zeros((s,), dtype=np.int32),
        np.zeros((s,), dtype=np.int32),
        np.zeros((s,), dtype=np.int32),
    )


def _write_row(out, which: int, i: int, keys, vals):
    k_arr, v_arr = out[2 * which], out[2 * which + 1]
    k_arr[i, : len(keys)] = np.asarray(keys, dtype=np.int32)
    v_arr[i, : len(vals)] = np.asarray(vals, dtype=np.float32)
