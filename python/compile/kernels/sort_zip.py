"""L1 Pallas kernels: the SparseZipper matrix unit's sort/zip datapath.

Hardware adaptation (DESIGN.md §5): the paper's systolic compare-exchange
wavefront becomes a **bitonic compare-exchange network** over the lane
dimension, with the compress pass realized as a prefix-sum segment-reduce —
the natural TPU formulation of the same comparator work. One grid program
processes one stream (one matrix-register row), so a [S, N] tile group maps
exactly onto the paper's "16 streams per instruction".

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the AOT artifacts must run inside the Rust coordinator via
the XLA CPU client (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain int (not a traced jnp constant): pallas kernels must not capture
# array-valued closure constants.
KEY_PAD = 2**31 - 1


# ---------------------------------------------------------------------------
# Compare-exchange primitives (shared by both kernels)
# ---------------------------------------------------------------------------

def _bitonic_sort(keys, vals):
    """Bitonic sort of a power-of-two lane vector, carrying values.

    log2(n)*(log2(n)+1)/2 compare-exchange stages, each a vectorized
    min/max/select over all lanes — the TPU re-expression of the paper's
    triangular comparator wavefront (same comparator count, lane-parallel).
    """
    n = keys.shape[-1]
    assert n & (n - 1) == 0, "lane count must be a power of two"
    idx = jnp.arange(n, dtype=jnp.int32)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            partner = idx ^ j
            pk = jnp.take(keys, partner)
            pv = jnp.take(vals, partner)
            self_is_lo = idx < partner
            # Normalize each pair to (a = low-lane datum, b = high-lane datum).
            a_k = jnp.where(self_is_lo, keys, pk)
            a_v = jnp.where(self_is_lo, vals, pv)
            b_k = jnp.where(self_is_lo, pk, keys)
            b_v = jnp.where(self_is_lo, pv, vals)
            swap = a_k > b_k  # strict: ties keep order, no duplication
            lo_k = jnp.where(swap, b_k, a_k)
            lo_v = jnp.where(swap, b_v, a_v)
            hi_k = jnp.where(swap, a_k, b_k)
            hi_v = jnp.where(swap, a_v, b_v)
            ascending = (idx & k) == 0
            keys = jnp.where(
                ascending,
                jnp.where(self_is_lo, lo_k, hi_k),
                jnp.where(self_is_lo, hi_k, lo_k),
            )
            vals = jnp.where(
                ascending,
                jnp.where(self_is_lo, lo_v, hi_v),
                jnp.where(self_is_lo, hi_v, lo_v),
            )
            j //= 2
        k *= 2
    return keys, vals


def _combine_compress(keys, vals, out_n):
    """Compress pass: combine equal-key runs (sum values), pack left.

    Prefix-sum formulation: segment starts -> segment ranks (cumsum) ->
    segment-sum of values -> scatter firsts to their rank. Returns
    (out_keys[out_n], out_vals[out_n], unique_count) with KEY_PAD padding.
    """
    n = keys.shape[-1]
    valid = keys != KEY_PAD
    prev = jnp.concatenate([jnp.full((1,), -1, dtype=keys.dtype), keys[:-1]])
    seg_start = valid & (keys != prev)
    # Rank of each lane's segment among valid segments (0-based).
    rank = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    unique = jnp.sum(seg_start.astype(jnp.int32))
    rank_clamped = jnp.clip(rank, 0, n - 1)
    seg_vals = jax.ops.segment_sum(
        jnp.where(valid, vals, 0.0), rank_clamped, num_segments=n
    )
    out_keys = jnp.full((n,), KEY_PAD, dtype=keys.dtype)
    out_keys = out_keys.at[jnp.where(seg_start, rank_clamped, n - 1)].set(
        jnp.where(seg_start, keys, KEY_PAD), mode="drop"
    )
    # Defensive re-pad: lanes at or past `unique` hold no segment.
    lane = jnp.arange(n, dtype=jnp.int32)
    out_keys = jnp.where(lane < unique, out_keys, KEY_PAD)
    out_vals = jnp.where(lane < unique, seg_vals, 0.0).astype(vals.dtype)
    return out_keys[:out_n], out_vals[:out_n], unique


# ---------------------------------------------------------------------------
# mssortk/mssortv: sort two chunks independently
# ---------------------------------------------------------------------------

def _sort_kernel(k0, v0, k1, v1, l0, l1, ok0, ov0, ok1, ov1, ic0, ic1, oc0, oc1):
    n = k0.shape[-1]
    lane = jnp.arange(n, dtype=jnp.int32)

    def one(kr, vr, lr):
        length = lr[0]
        keys = jnp.where(lane < length, kr[0], KEY_PAD)
        vals = jnp.where(lane < length, vr[0], 0.0)
        keys, vals = _bitonic_sort(keys, vals)
        out_k, out_v, unique = _combine_compress(keys, vals, n)
        return out_k, out_v, unique

    a_k, a_v, a_u = one(k0, v0, l0)
    b_k, b_v, b_u = one(k1, v1, l1)
    ok0[0, :] = a_k
    ov0[0, :] = a_v
    ok1[0, :] = b_k
    ov1[0, :] = b_v
    ic0[0] = l0[0]
    ic1[0] = l1[0]
    oc0[0] = a_u
    oc1[0] = b_u


# ---------------------------------------------------------------------------
# mszipk/mszipv: merge two sorted chunks
# ---------------------------------------------------------------------------

def _zip_kernel(k0, v0, k1, v1, l0, l1, ok0, ov0, ok1, ov1, ic0, ic1, oc0, oc1):
    n = k0.shape[-1]
    lane = jnp.arange(n, dtype=jnp.int32)
    la, lb = l0[0], l1[0]
    a = jnp.where(lane < la, k0[0], KEY_PAD)
    av = jnp.where(lane < la, v0[0], 0.0)
    b = jnp.where(lane < lb, k1[0], KEY_PAD)
    bv = jnp.where(lane < lb, v1[0], 0.0)

    # Merge-bit rule (prefix form): x in A mergeable iff x <= max(B).
    max_a = jnp.max(jnp.where(lane < la, a, -1))
    max_b = jnp.max(jnp.where(lane < lb, b, -1))
    merge_a = (lane < la) & (a <= max_b)
    merge_b = (lane < lb) & (b <= max_a)
    consumed_a = jnp.sum(merge_a.astype(jnp.int32))
    consumed_b = jnp.sum(merge_b.astype(jnp.int32))

    # Bitonic merge of the mergeable union (2N lanes), then compress.
    c = jnp.concatenate([jnp.where(merge_a, a, KEY_PAD), jnp.where(merge_b, b, KEY_PAD)])
    cv = jnp.concatenate([jnp.where(merge_a, av, 0.0), jnp.where(merge_b, bv, 0.0)])
    c, cv = _bitonic_sort(c, cv)
    m_k, m_v, unique = _combine_compress(c, cv, 2 * n)

    east = jnp.minimum(unique, n)
    ok0[0, :] = m_k[:n]
    ov0[0, :] = m_v[:n]
    ok1[0, :] = m_k[n:]
    ov1[0, :] = m_v[n:]
    ic0[0] = consumed_a
    ic1[0] = consumed_b
    oc0[0] = east
    oc1[0] = unique - east


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _step_call(kernel, s: int, n: int):
    row = pl.BlockSpec((1, n), lambda i: (i, 0))
    scl = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(s,),
        in_specs=[row, row, row, row, scl, scl],
        out_specs=[row, row, row, row, scl, scl, scl, scl],
        out_shape=[
            jax.ShapeDtypeStruct((s, n), jnp.int32),
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, n), jnp.int32),
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )


@functools.partial(jax.jit, static_argnames=("s", "n"))
def sort_step(k0, v0, k1, v1, l0, l1, *, s: int = 16, n: int = 16):
    """Batched mssortk+mssortv over a [s, n] stream group."""
    return tuple(_step_call(_sort_kernel, s, n)(k0, v0, k1, v1, l0, l1))


@functools.partial(jax.jit, static_argnames=("s", "n"))
def zip_step(k0, v0, k1, v1, l0, l1, *, s: int = 16, n: int = 16):
    """Batched mszipk+mszipv over a [s, n] stream group."""
    return tuple(_step_call(_zip_kernel, s, n)(k0, v0, k1, v1, l0, l1))
