"""AOT lowering: L2 model (wrapping the L1 Pallas kernels) -> HLO text.

HLO *text* is the interchange format (NOT ``HloModuleProto.serialize()``):
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowered with return_tuple=True;
the Rust side unwraps via ``Literal::to_tuple``.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Usage: python -m compile.aot --out-dir ../artifacts [--s 16] [--n 16]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(fn, s: int, n: int) -> str:
    mat_i = jax.ShapeDtypeStruct((s, n), jnp.int32)
    mat_f = jax.ShapeDtypeStruct((s, n), jnp.float32)
    vec_i = jax.ShapeDtypeStruct((s,), jnp.int32)

    def wrapped(k0, v0, k1, v1, l0, l1):
        return fn(k0, v0, k1, v1, l0, l1, s=s, n=n)

    lowered = jax.jit(wrapped).lower(mat_i, mat_f, mat_i, mat_f, vec_i, vec_i)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--s", type=int, default=16, help="streams per group")
    ap.add_argument("--n", type=int, default=16, help="chunk size (register row)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = [
        f"s={args.s}",
        f"n={args.n}",
        "inputs=k0:i32[s,n] v0:f32[s,n] k1:i32[s,n] v1:f32[s,n] l0:i32[s] l1:i32[s]",
        "outputs=k0':i32[s,n] v0':f32[s,n] k1':i32[s,n] v1':f32[s,n] "
        "ic0:i32[s] ic1:i32[s] oc0:i32[s] oc1:i32[s]",
        f"key_pad={2**31 - 1}",
        f"jax={jax.__version__}",
    ]
    for name, fn in [("sort_step", model.sort_step), ("zip_step", model.zip_step)]:
        text = lower_step(fn, args.s, args.n)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}: {len(text)} chars")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
