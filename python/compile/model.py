"""L2 JAX model: the matrix unit's functional datapath over the L1 kernels.

Two exported computations (the AOT artifacts loaded by the Rust runtime):

  * ``sort_step`` — mssortk+mssortv over a [S, N] stream group;
  * ``zip_step``  — mszipk+mszipv over a [S, N] stream group.

Plus a composed demonstration graph, ``merge_partitions``, that runs the
chunk-at-a-time zip loop (paper Figure 2 / Figure 4b) as a
``lax.while_loop`` — used by the python tests to show the L2 layer can
express the full software merge loop around the L1 kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.sort_zip import sort_step, zip_step, KEY_PAD

__all__ = ["sort_step", "zip_step", "merge_partitions", "KEY_PAD"]


@functools.partial(jax.jit, static_argnames=("n", "max_len"))
def merge_partitions(pa_k, pa_v, la, pb_k, pb_v, lb, *, n: int = 16, max_len: int = 256):
    """Merge two sorted-unique partitions of a single stream with the
    chunk-at-a-time zip loop (Fig. 2): load <=N-element chunks from each
    partition, zip_step them, advance by the IC counters, append east+south
    to the output, and tail-copy when one side empties.

    Inputs are KEY_PAD-padded [max_len] vectors with scalar lengths.
    Returns (out_k[2*max_len], out_v, out_len).
    """

    def body(st):
        ia, ib, out_k, out_v, out_len = st
        ra = la - ia
        rb = lb - ib
        ca = jnp.minimum(ra, n)
        cb = jnp.minimum(rb, n)
        lane = jnp.arange(n, dtype=jnp.int32)
        a_k = jnp.where(lane < ca, lax.dynamic_slice(pa_k, (ia,), (n,)), KEY_PAD)[None, :]
        a_v = jnp.where(lane < ca, lax.dynamic_slice(pa_v, (ia,), (n,)), 0.0)[None, :]
        b_k = jnp.where(lane < cb, lax.dynamic_slice(pb_k, (ib,), (n,)), KEY_PAD)[None, :]
        b_v = jnp.where(lane < cb, lax.dynamic_slice(pb_v, (ib,), (n,)), 0.0)[None, :]
        ok0, ov0, ok1, ov1, ic0, ic1, oc0, oc1 = zip_step(
            a_k, a_v, b_k, b_v, ca[None], cb[None], s=1, n=n
        )
        merged_k = jnp.concatenate([ok0[0], ok1[0]])
        merged_v = jnp.concatenate([ov0[0], ov1[0]])
        mlen = oc0[0] + oc1[0]
        # Append merged chunk at out_len.
        lane2 = jnp.arange(2 * n, dtype=jnp.int32)
        upd_k = jnp.where(lane2 < mlen, merged_k, lax.dynamic_slice(out_k, (out_len,), (2 * n,)))
        upd_v = jnp.where(lane2 < mlen, merged_v, lax.dynamic_slice(out_v, (out_len,), (2 * n,)))
        out_k = lax.dynamic_update_slice(out_k, upd_k, (out_len,))
        out_v = lax.dynamic_update_slice(out_v, upd_v, (out_len,))
        return ia + ic0[0], ib + ic1[0], out_k, out_v, out_len + mlen

    def cond(st):
        ia, ib, _, _, _ = st
        return (ia < la) & (ib < lb)

    pad = 2 * max_len + 2 * n  # slack so dynamic_update_slice never clips
    out_k0 = jnp.full((pad,), KEY_PAD, dtype=jnp.int32)
    out_v0 = jnp.zeros((pad,), dtype=jnp.float32)
    ia, ib, out_k, out_v, out_len = lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), out_k0, out_v0, jnp.int32(0))
    )

    # Tail copy: one side exhausted; append the remainder of the other.
    def tail(src_k, src_v, i0, length, out_k, out_v, out_len):
        def tbody(st):
            i, out_k, out_v, out_len = st
            c = jnp.minimum(length - i, n)
            lane = jnp.arange(n, dtype=jnp.int32)
            chunk_k = jnp.where(lane < c, lax.dynamic_slice(src_k, (i,), (n,)), KEY_PAD)
            chunk_v = jnp.where(lane < c, lax.dynamic_slice(src_v, (i,), (n,)), 0.0)
            upd_k = jnp.where(lane < c, chunk_k, lax.dynamic_slice(out_k, (out_len,), (n,)))
            upd_v = jnp.where(lane < c, chunk_v, lax.dynamic_slice(out_v, (out_len,), (n,)))
            out_k = lax.dynamic_update_slice(out_k, upd_k, (out_len,))
            out_v = lax.dynamic_update_slice(out_v, upd_v, (out_len,))
            return i + c, out_k, out_v, out_len + c

        def tcond(st):
            i, _, _, _ = st
            return i < length

        _, out_k, out_v, out_len = lax.while_loop(tcond, tbody, (i0, out_k, out_v, out_len))
        return out_k, out_v, out_len

    out_k, out_v, out_len = tail(pa_k, pa_v, ia, la, out_k, out_v, out_len)
    out_k, out_v, out_len = tail(pb_k, pb_v, ib, lb, out_k, out_v, out_len)
    return out_k[: 2 * max_len], out_v[: 2 * max_len], out_len
