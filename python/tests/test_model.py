"""L2 model tests: the composed merge_partitions graph (lax.while_loop over
the L1 zip_step kernel) fully merges two sorted partitions, matching a plain
numpy merge — evidence the L2 layer can express the paper's Figure 2/4b
software loop around the kernel."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def pad(vec, n, fill):
    out = np.full((n,), fill, dtype=np.int32 if fill == model.KEY_PAD else np.float32)
    out[: len(vec)] = vec
    return out


def run_merge(a_keys, b_keys, max_len=64):
    a_keys = sorted(set(a_keys))
    b_keys = sorted(set(b_keys))
    av = [1.0 + 0.5 * i for i in range(len(a_keys))]
    bv = [2.0 + 0.25 * i for i in range(len(b_keys))]
    out_k, out_v, out_len = model.merge_partitions(
        pad(a_keys, max_len, model.KEY_PAD).astype(np.int32),
        pad(av, max_len, 0.0).astype(np.float32),
        np.int32(len(a_keys)),
        pad(b_keys, max_len, model.KEY_PAD).astype(np.int32),
        pad(bv, max_len, 0.0).astype(np.float32),
        np.int32(len(b_keys)),
        n=16,
        max_len=max_len,
    )
    ln = int(out_len)
    got_k = list(np.asarray(out_k)[:ln])
    got_v = list(np.asarray(out_v)[:ln])
    # numpy reference merge
    acc = {}
    for k, v in list(zip(a_keys, av)) + list(zip(b_keys, bv)):
        acc[k] = acc.get(k, 0.0) + v
    want_k = sorted(acc)
    want_v = [acc[k] for k in want_k]
    return got_k, got_v, want_k, want_v


def test_merge_disjoint():
    gk, gv, wk, wv = run_merge([1, 3, 5, 7], [2, 4, 6, 8])
    assert gk == wk
    np.testing.assert_allclose(gv, wv, rtol=1e-5)


def test_merge_with_duplicates():
    gk, gv, wk, wv = run_merge([1, 2, 3, 10, 20], [2, 3, 4, 20, 30])
    assert gk == wk
    np.testing.assert_allclose(gv, wv, rtol=1e-5)


def test_merge_empty_sides():
    gk, gv, wk, wv = run_merge([], [5, 6])
    assert gk == wk == [5, 6]
    gk, gv, wk, wv = run_merge([1], [])
    assert gk == wk == [1]


def test_merge_long_partitions():
    a = list(range(0, 120, 2))
    b = list(range(1, 120, 3))
    gk, gv, wk, wv = run_merge(a, b)
    assert gk == wk
    np.testing.assert_allclose(gv, wv, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(0, 100), max_size=50),
    st.lists(st.integers(0, 100), max_size=50),
)
def test_merge_random(a, b):
    gk, gv, wk, wv = run_merge(a, b)
    assert gk == wk
    np.testing.assert_allclose(gv, wv, rtol=1e-4)


def test_aot_lowering_produces_hlo_text():
    from compile import aot

    text = aot.lower_step(model.sort_step, 2, 8)
    assert text.startswith("HloModule") or "ENTRY" in text
    assert len(text) > 1000
