"""L1 kernel correctness: Pallas sort/zip steps vs the numpy oracle.

Golden tests pin the paper's Figure 5 examples (the same goldens exist on
the Rust side, keeping oracle and engine in lock-step); hypothesis sweeps
shapes, lengths, duplicate densities, and value ranges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sort_zip import sort_step, zip_step, KEY_PAD

N = 16


def pack(streams_k, streams_v, s, n):
    k = np.full((s, n), ref.KEY_PAD, dtype=np.int32)
    v = np.zeros((s, n), dtype=np.float32)
    lens = np.zeros((s,), dtype=np.int32)
    for i, (ks, vs) in enumerate(zip(streams_k, streams_v)):
        k[i, : len(ks)] = ks
        v[i, : len(vs)] = vs
        lens[i] = len(ks)
    return k, v, lens


def run_both(fn_jax, fn_ref, k0, v0, k1, v1, l0, l1, s, n):
    got = fn_jax(k0, v0, k1, v1, l0, l1, s=s, n=n)
    want = fn_ref(k0, v0, k1, v1, l0, l1, n)
    for gi, wi, name in zip(got, want, ["k0", "v0", "k1", "v1", "ic0", "ic1", "oc0", "oc1"]):
        g = np.asarray(gi)
        w = np.asarray(wi)
        if g.dtype.kind == "f":
            # Mask to valid lanes (padding values are free).
            if name == "v0":
                lens = np.asarray(want[6])
            else:
                lens = np.asarray(want[7])
            for row in range(s):
                np.testing.assert_allclose(
                    g[row, : lens[row]], w[row, : lens[row]], rtol=1e-5, atol=1e-5,
                    err_msg=f"{name} row {row}",
                )
        else:
            if name in ("k0", "k1"):
                lens = np.asarray(want[6] if name == "k0" else want[7])
                for row in range(s):
                    np.testing.assert_array_equal(
                        g[row, : lens[row]], w[row, : lens[row]], err_msg=f"{name} row {row}"
                    )
                    assert (g[row, lens[row]:] == ref.KEY_PAD).all(), f"{name} row {row} padding"
            else:
                np.testing.assert_array_equal(g, w, err_msg=name)
    return got, want


# --- goldens: paper Figure 5 ------------------------------------------------

def test_fig5a_sort_golden():
    # North chunk {5, 8, 5} -> {5, 8} with values combined; west {4, 1, 6}.
    k0, v0, l0 = pack([[4, 1, 6]], [[1.0, 2.0, 3.0]], 1, N)
    k1, v1, l1 = pack([[5, 8, 5]], [[1.0, 2.0, 4.0]], 1, N)
    got, _ = run_both(sort_step, ref.sort_step_ref, k0, v0, k1, v1, l0, l1, 1, N)
    assert list(np.asarray(got[0])[0, :3]) == [1, 4, 6]
    assert list(np.asarray(got[2])[0, :2]) == [5, 8]
    np.testing.assert_allclose(np.asarray(got[3])[0, :2], [5.0, 2.0])
    assert int(np.asarray(got[7])[0]) == 2


def test_fig5b_zip_golden():
    # West {2,5,9}, north {3,8}: east {2,3,5}, south {8}, 9 unmergeable.
    n = 16
    k0, v0, l0 = pack([[2, 5, 9]], [[1.0, 2.0, 3.0]], 1, n)
    k1, v1, l1 = pack([[3, 8]], [[4.0, 5.0]], 1, n)
    got = zip_step(k0, v0, k1, v1, l0, l1, s=1, n=n)
    east_len = int(np.asarray(got[6])[0])
    east = list(np.asarray(got[0])[0, :east_len])
    assert east == [2, 3, 5, 8]  # n=16 > merged size, all land east
    assert int(np.asarray(got[4])[0]) == 2  # IC0: 9 excluded
    assert int(np.asarray(got[5])[0]) == 2  # IC1


def test_zip_cross_duplicates_combine():
    k0, v0, l0 = pack([[1, 4, 7]], [[1.0, 2.0, 3.0]], 1, N)
    k1, v1, l1 = pack([[4, 9]], [[10.0, 20.0]], 1, N)
    got, want = run_both(zip_step, ref.zip_step_ref, k0, v0, k1, v1, l0, l1, 1, N)
    east_len = int(np.asarray(got[6])[0])
    assert list(np.asarray(got[0])[0, :east_len]) == [1, 4, 7]
    np.testing.assert_allclose(np.asarray(got[1])[0, :east_len], [1.0, 12.0, 3.0])


def test_zip_empty_sides():
    k0, v0, l0 = pack([[1, 2]], [[1.0, 1.0]], 1, N)
    k1, v1, l1 = pack([[]], [[]], 1, N)
    got, want = run_both(zip_step, ref.zip_step_ref, k0, v0, k1, v1, l0, l1, 1, N)
    assert int(np.asarray(got[4])[0]) == 0
    assert int(np.asarray(got[6])[0]) == 0


def test_sort_all_duplicates():
    k0, v0, l0 = pack([[3] * 10], [[1.0] * 10], 1, N)
    k1, v1, l1 = pack([[7, 7]], [[2.0, 3.0]], 1, N)
    got, _ = run_both(sort_step, ref.sort_step_ref, k0, v0, k1, v1, l0, l1, 1, N)
    assert int(np.asarray(got[6])[0]) == 1
    np.testing.assert_allclose(np.asarray(got[1])[0, 0], 10.0)


# --- hypothesis sweeps -------------------------------------------------------

chunk = st.integers(min_value=0, max_value=N).flatmap(
    lambda ln: st.tuples(
        st.lists(st.integers(0, 40), min_size=ln, max_size=ln),
        st.lists(st.floats(0.5, 1.5, width=32), min_size=ln, max_size=ln),
    )
)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(chunk, chunk), min_size=1, max_size=4))
def test_sort_step_matches_ref(streams):
    s = len(streams)
    k0, v0, l0 = pack([c[0][0] for c in streams], [c[0][1] for c in streams], s, N)
    k1, v1, l1 = pack([c[1][0] for c in streams], [c[1][1] for c in streams], s, N)
    run_both(sort_step, ref.sort_step_ref, k0, v0, k1, v1, l0, l1, s, N)


def sorted_unique(lst):
    return sorted(set(lst))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(chunk, chunk), min_size=1, max_size=4))
def test_zip_step_matches_ref(streams):
    s = len(streams)
    ak = [sorted_unique(c[0][0]) for c in streams]
    bk = [sorted_unique(c[1][0]) for c in streams]
    av = [[1.0 + 0.25 * i for i in range(len(k))] for k in ak]
    bv = [[2.0 + 0.5 * i for i in range(len(k))] for k in bk]
    k0, v0, l0 = pack(ak, av, s, N)
    k1, v1, l1 = pack(bk, bv, s, N)
    run_both(zip_step, ref.zip_step_ref, k0, v0, k1, v1, l0, l1, s, N)


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(0, 30), min_size=0, max_size=8),
    st.lists(st.integers(0, 30), min_size=0, max_size=8),
)
def test_zip_step_smaller_n(a, b):
    # Shape generality: n = 8 (different register geometry).
    n = 8
    a, b = sorted_unique(a), sorted_unique(b)
    k0, v0, l0 = pack([a], [[1.0] * len(a)], 1, n)
    k1, v1, l1 = pack([b], [[1.0] * len(b)], 1, n)
    run_both(zip_step, ref.zip_step_ref, k0, v0, k1, v1, l0, l1, 1, n)


def test_dtypes():
    k0, v0, l0 = pack([[1]], [[1.0]], 1, N)
    out = sort_step(k0, v0, k0, v0, l0, l0, s=1, n=N)
    assert np.asarray(out[0]).dtype == np.int32
    assert np.asarray(out[1]).dtype == np.float32
    assert np.asarray(out[6]).dtype == np.int32


def test_key_pad_constant_matches_ref():
    assert int(KEY_PAD) == int(ref.KEY_PAD) == 2**31 - 1
