#!/usr/bin/env python3
"""Record or diff BENCH_*.json perf baselines from bench_util output.

The rust benches print one line per measurement in one of two shapes:

    bench <name>    median   12.345 ms   mean   13.0 ms   min   11.9 ms   (3 reps)
    bench <name>       42.7 ns/op   (123456 ops)

`record` fills the matching `series` entries of a baseline JSON in place
(plus `host`, `recorded_utc`, and `status: "measured"`); `delta` prints a
markdown table comparing fresh output against the stored medians without
touching the file. Both read bench output from stdin:

    cargo bench --bench replay_shards 2>&1 \
        | python3 tools/perf_baseline.py record BENCH_replay.json
    cargo bench --bench replay_shards 2>&1 \
        | python3 tools/perf_baseline.py delta BENCH_replay.json
"""

import json
import re
import socket
import sys
from datetime import datetime, timezone

MEDIAN_RE = re.compile(r"^bench\s+(.*?)\s+median\s+([0-9.]+)\s+ms\b")
NSOP_RE = re.compile(r"^bench\s+(.*?)\s+([0-9.]+)\s+ns/op\b")


def norm(s):
    """Fold a bench name / series key to a comparable token string."""
    return re.sub(r"[^a-z0-9=]+", "_", s.lower()).strip("_")


def parse(stream):
    """-> {printed bench name: measured value} (ms medians and ns/op)."""
    out = {}
    for line in stream:
        m = MEDIAN_RE.match(line.strip()) or NSOP_RE.match(line.strip())
        if m:
            out[m.group(1).strip()] = float(m.group(2))
    return out


def match(key, measured):
    """Find the measured value for a series key (exact, then normalized)."""
    if key in measured:
        return measured[key]
    nk = norm(key)
    for name, v in measured.items():
        if norm(name) == nk:
            return v
    # Runtime-formatted suffixes ("SimService submit/wait x256"): accept a
    # unique prefix match.
    pref = [v for name, v in measured.items() if norm(name).startswith(nk)]
    if len(pref) == 1:
        return pref[0]
    return None


def each_series(doc):
    for bench_name, bench in doc.get("benches", {}).items():
        for key in bench.get("series", {}):
            yield bench_name, bench, key


def cmd_record(path, measured):
    with open(path) as f:
        doc = json.load(f)
    filled, missing = 0, []
    for bench_name, bench, key in each_series(doc):
        v = match(key, measured)
        if v is None:
            missing.append(f"{bench_name}/{key}")
        else:
            bench["series"][key] = v
            filled += 1
    # Derived ratios where both ends landed: the historical
    # speedup_<N>shard_over_serial form, plus the generic
    # speedup_<X>_over_<Y> (= time(Y) / time(X), both resolved against the
    # same bench's series keys through the usual normalization).
    for bench in doc.get("benches", {}).values():
        derived = bench.get("derived", {})
        series = bench.get("series", {})
        for dkey in derived:
            m = re.match(r"speedup_(\d+)shard_over_serial$", dkey)
            if m:
                base = match("replay shards=1", series) if series else None
                shard = match(f"replay shards={m.group(1)}", series) if series else None
            else:
                m = re.match(r"speedup_(.+)_over_(.+)$", dkey)
                if not m:
                    continue
                base = match(m.group(2), series) if series else None
                shard = match(m.group(1), series) if series else None
            if base and shard:
                derived[dkey] = round(base / shard, 3)
    if filled:
        doc["status"] = "measured"
        doc["host"] = socket.gethostname()
        doc["recorded_utc"] = datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"{path}: filled {filled} series entr{'y' if filled == 1 else 'ies'}")
    for key in missing:
        print(f"  no measurement matched {key}", file=sys.stderr)
    return 0 if filled else 1


def cmd_delta(path, measured):
    with open(path) as f:
        doc = json.load(f)
    rows = []
    for bench_name, bench, key in each_series(doc):
        base = bench["series"][key]
        fresh = match(key, measured)
        unit = bench.get("unit", "")
        if fresh is None:
            continue
        if base is None:
            rows.append((f"{bench_name}/{key}", "n/a", f"{fresh:.3f}", unit, "baseline unmeasured"))
        else:
            pct = 100.0 * (fresh - base) / base if base else 0.0
            rows.append((f"{bench_name}/{key}", f"{base:.3f}", f"{fresh:.3f}", unit, f"{pct:+.1f}%"))
    if not rows:
        print("no bench lines matched the baseline series", file=sys.stderr)
        return 1
    print("| bench | baseline | fresh | unit | delta |")
    print("|---|---:|---:|---|---:|")
    for r in rows:
        print("| " + " | ".join(r) + " |")
    return 0


def main(argv):
    if len(argv) != 3 or argv[1] not in ("record", "delta"):
        print(__doc__, file=sys.stderr)
        return 2
    measured = parse(sys.stdin)
    if argv[1] == "record":
        return cmd_record(argv[2], measured)
    return cmd_delta(argv[2], measured)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
