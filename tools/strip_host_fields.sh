# Shared sed programs for byte-diffing spz suite JSON across runs.
#
# Source this file (`. ../tools/strip_host_fields.sh` from rust/), then pipe
# through `sed "$STRIP_HOST_FIELDS"` or `sed "$STRIP_RING_FIELDS"`. Every CI
# byte-diff step uses these definitions so the list of host-artifact fields
# lives in exactly one place.
#
# STRIP_HOST_FIELDS removes the fields that legitimately differ between two
# runs of the *same* configuration: each job's host wall-clock and the
# service pool's queue/slot high-water marks (how far the pool happened to
# run ahead of the submitter). Every simulated number — cycles, stalls,
# coherence counters, NUMA charges, oracle traffic — must survive the strip
# and match exactly.
#
# STRIP_RING_FIELDS additionally removes the two ring-shaped trace counters
# (peak resident chunks, spilled chunks) — the quantities
# --trace-ring-chunks exists to change — for diffs *across* ring
# configurations.

STRIP_HOST_FIELDS='s/"wall_secs":[^,]*,//g; s/"queue_depth_high_water":[^,]*,//g; s/"slots_high_water":[^,]*,//g'
STRIP_RING_FIELDS="$STRIP_HOST_FIELDS"'; s/"trace_peak_resident_chunks":[^,]*,//g; s/"spilled_chunks":[^,}]*//g'
